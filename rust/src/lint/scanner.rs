//! Line-based source scanner: strips comments and string/char literals,
//! tracks brace depth and the innermost enclosing `fn`, and extracts
//! `// lint: allow(rule, "reason")` pragmas.
//!
//! This is deliberately NOT a parser — the contract rules in
//! [`super::rules`] are token-level conventions (a call name, an
//! iteration verb, a lock idiom), so a stripped-text view plus
//! lightweight scope tracking is enough, keeps the pass dependency-free
//! (no `syn` in the offline image), and makes diagnostics trivially
//! line-addressable.

/// One source line after stripping: comments and literal contents are
/// replaced by spaces so column-free token matching cannot fire inside
/// them.
pub struct ScannedLine {
    /// 1-based line number.
    pub number: usize,
    /// The line with comments and string/char literal contents blanked.
    pub code: String,
    /// Name of the innermost `fn` whose body contains the START of this
    /// line, when known.
    pub enclosing_fn: Option<String>,
    /// Whether a `#[cfg(test)]` attribute has been seen at or above
    /// this line. Test modules sit at the tail of every file in this
    /// repo (rustfmt convention), so "everything after the attribute"
    /// is an accurate test-region approximation for a line-based pass.
    pub in_test: bool,
}

/// A `// lint: allow(rule, "reason")` pragma.
pub struct Pragma {
    /// 1-based line the pragma comment sits on.
    pub line: usize,
    /// The rule id inside `allow(...)`.
    pub rule: String,
    /// The reason string, when present and non-empty.
    pub reason: Option<String>,
}

/// A fully scanned file: stripped lines plus the pragmas found in its
/// comments.
pub struct ScannedFile {
    /// Path relative to the lint root, forward slashes.
    pub path: String,
    /// Stripped lines, in order.
    pub lines: Vec<ScannedLine>,
    /// Pragmas, in line order.
    pub pragmas: Vec<Pragma>,
}

/// Cross-line lexer state.
enum Mode {
    /// Ordinary code.
    Code,
    /// Inside a `"..."` string literal.
    Str,
    /// Inside an `r"..."` / `r#"..."#` raw string with `hashes` hashes.
    RawStr { hashes: usize },
    /// Inside a (possibly nested) `/* ... */` block comment.
    Block { depth: usize },
}

/// Scan `content` (the text of one Rust source file) into stripped
/// lines, scopes, and pragmas. `path` is carried through verbatim for
/// diagnostics.
pub fn scan(path: &str, content: &str) -> ScannedFile {
    let mut mode = Mode::Code;
    let mut lines = Vec::new();
    let mut pragmas = Vec::new();
    // Brace/scope tracking: current depth, the stack of (open depth,
    // fn name) for bodies of named fns, and a pending fn whose body
    // brace has not opened yet (signatures span lines).
    let mut depth = 0usize;
    let mut fn_stack: Vec<(usize, String)> = Vec::new();
    let mut pending_fn: Option<String> = None;
    // Bracket nesting inside a pending signature, so a `;` inside
    // `[u8; 4]` does not cancel the pending fn.
    let mut pending_brackets = 0usize;
    let mut in_test = false;

    for (number, raw) in content.lines().enumerate() {
        let number = number + 1;
        let enclosing_fn = fn_stack.last().map(|(_, name)| name.clone());
        let (code, comment) = strip_line(raw, &mut mode);
        if code.trim_start().starts_with("#[cfg(test)]") {
            in_test = true;
        }
        if let Some(comment) = comment {
            if let Some(pragma) = parse_pragma(number, &comment) {
                pragmas.push(pragma);
            }
        }

        // Scope pass over the stripped code.
        let bytes = code.as_bytes();
        let mut i = 0;
        while i < bytes.len() {
            match bytes[i] {
                b'{' => {
                    if let Some(name) = pending_fn.take() {
                        fn_stack.push((depth, name));
                        pending_brackets = 0;
                    }
                    depth += 1;
                }
                b'}' => {
                    depth = depth.saturating_sub(1);
                    if fn_stack.last().is_some_and(|(open, _)| *open == depth) {
                        fn_stack.pop();
                    }
                }
                b'(' | b'[' if pending_fn.is_some() => pending_brackets += 1,
                b')' | b']' if pending_fn.is_some() => {
                    pending_brackets = pending_brackets.saturating_sub(1);
                }
                b';' if pending_fn.is_some() && pending_brackets == 0 => {
                    // Trait method declaration without a body.
                    pending_fn = None;
                }
                b'f' if is_keyword_at(&code, i, "fn") => {
                    if let Some(name) = ident_after(&code, i + 2) {
                        pending_fn = Some(name);
                        pending_brackets = 0;
                    }
                    i += 1;
                }
                _ => {}
            }
            i += 1;
        }

        lines.push(ScannedLine { number, code, enclosing_fn, in_test });
    }

    ScannedFile { path: path.to_string(), lines, pragmas }
}

/// Strip one raw line under the running lexer `mode`. Returns the
/// blanked code text and, when a `//` comment starts on this line, its
/// text (for pragma parsing).
fn strip_line(raw: &str, mode: &mut Mode) -> (String, Option<String>) {
    let mut code = String::with_capacity(raw.len());
    let mut comment = None;
    let bytes = raw.as_bytes();
    let mut i = 0;
    while i < bytes.len() {
        match mode {
            Mode::Block { depth } => {
                if bytes[i..].starts_with(b"*/") {
                    *depth -= 1;
                    i += 2;
                    if *depth == 0 {
                        *mode = Mode::Code;
                    }
                } else if bytes[i..].starts_with(b"/*") {
                    *depth += 1;
                    i += 2;
                } else {
                    i += 1;
                }
                continue;
            }
            Mode::Str => {
                if bytes[i] == b'\\' {
                    i += 2;
                } else if bytes[i] == b'"' {
                    *mode = Mode::Code;
                    code.push(' ');
                    i += 1;
                } else {
                    i += 1;
                }
                continue;
            }
            Mode::RawStr { hashes } => {
                let closer_len = 1 + *hashes;
                if bytes[i] == b'"'
                    && bytes[i + 1..].len() >= *hashes
                    && bytes[i + 1..i + closer_len].iter().all(|&b| b == b'#')
                {
                    i += closer_len;
                    *mode = Mode::Code;
                    code.push(' ');
                } else {
                    i += 1;
                }
                continue;
            }
            Mode::Code => {}
        }
        // Mode::Code from here on.
        if bytes[i..].starts_with(b"//") {
            comment = Some(raw[i..].to_string());
            break;
        }
        if bytes[i..].starts_with(b"/*") {
            *mode = Mode::Block { depth: 1 };
            i += 2;
            continue;
        }
        if bytes[i] == b'"' {
            *mode = Mode::Str;
            code.push(' ');
            i += 1;
            continue;
        }
        if bytes[i] == b'r' && !prev_is_ident(bytes, i) {
            // Possible raw string r"..." / r#"..."#.
            let mut j = i + 1;
            while j < bytes.len() && bytes[j] == b'#' {
                j += 1;
            }
            if j < bytes.len() && bytes[j] == b'"' {
                *mode = Mode::RawStr { hashes: j - i - 1 };
                code.push(' ');
                i = j + 1;
                continue;
            }
        }
        if bytes[i] == b'\'' {
            // Char literal or lifetime. An escaped or single-char
            // literal closes with another quote; a lifetime does not.
            if let Some(consumed) = char_literal_len(&raw[i..]) {
                code.push(' ');
                i += consumed;
                continue;
            }
            // Lifetime marker: keep as-is (harmless to token matching).
            code.push('\'');
            i += 1;
            continue;
        }
        // Copy one full UTF-8 character.
        let ch_len = utf8_len(bytes[i]);
        code.push_str(&raw[i..i + ch_len]);
        i += ch_len;
    }
    (code, comment)
}

/// Byte length of a char literal starting at a `'`, or `None` when the
/// quote is a lifetime marker instead.
fn char_literal_len(s: &str) -> Option<usize> {
    let bytes = s.as_bytes();
    if bytes.len() < 3 {
        return None;
    }
    if bytes[1] == b'\\' {
        // Escaped literal: the byte after the backslash is part of the
        // escape (covers `'\''` and `'\\'`), then scan to the closing
        // quote (covers `'\n'`, `'\x41'`, `'\u{..}'`).
        if bytes.len() < 4 {
            return None;
        }
        let close = s[3..].find('\'')?;
        return Some(3 + close + 1);
    }
    // Unescaped: exactly one character then a closing quote.
    let mut chars = s[1..].char_indices();
    let (_, _first) = chars.next()?;
    match chars.next() {
        Some((offset, '\'')) => Some(1 + offset + 1),
        _ => None,
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        b if b < 0x80 => 1,
        b if b >= 0xF0 => 4,
        b if b >= 0xE0 => 3,
        _ => 2,
    }
}

fn prev_is_ident(bytes: &[u8], i: usize) -> bool {
    i > 0 && (bytes[i - 1].is_ascii_alphanumeric() || bytes[i - 1] == b'_')
}

/// Whether `word` starts at byte `i` of `code` with identifier
/// boundaries on both sides.
fn is_keyword_at(code: &str, i: usize, word: &str) -> bool {
    let bytes = code.as_bytes();
    if !code[i..].starts_with(word) || prev_is_ident(bytes, i) {
        return false;
    }
    match bytes.get(i + word.len()) {
        Some(&b) => !(b.is_ascii_alphanumeric() || b == b'_'),
        None => true,
    }
}

/// The identifier starting at or after byte `from` (skipping spaces).
fn ident_after(code: &str, from: usize) -> Option<String> {
    let rest = code.get(from..)?.trim_start();
    let end = rest
        .find(|c: char| !(c.is_ascii_alphanumeric() || c == '_'))
        .unwrap_or(rest.len());
    if end == 0 {
        return None;
    }
    Some(rest[..end].to_string())
}

/// Parse a `// lint: allow(rule, "reason")` pragma. Only a plain `//`
/// comment whose text STARTS with the pragma counts — doc comments and
/// prose that merely mention the syntax (this file does) are not
/// pragmas.
fn parse_pragma(line: usize, comment: &str) -> Option<Pragma> {
    let body = comment.strip_prefix("//")?;
    if body.starts_with('/') || body.starts_with('!') {
        return None;
    }
    let inner = body.trim_start().strip_prefix("lint: allow(")?;
    let close = inner.find(')')?;
    let inner = &inner[..close];
    let (rule, reason) = match inner.split_once(',') {
        Some((rule, reason)) => (rule, reason),
        None => (inner, ""),
    };
    let reason = reason.trim().trim_matches('"').trim();
    Some(Pragma {
        line,
        rule: rule.trim().to_string(),
        reason: (!reason.is_empty()).then(|| reason.to_string()),
    })
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used)]

    use super::*;

    #[test]
    fn strips_strings_comments_and_char_literals() {
        let src =
            "let x = \"HashMap.iter()\"; // HashMap.iter()\nlet c = '{'; let l: &'a str = s;\n";
        let file = scan("f.rs", src);
        assert!(!file.lines[0].code.contains("HashMap"));
        assert!(!file.lines[1].code.contains('{'));
        // The lifetime quote must not swallow the rest of the line.
        assert!(file.lines[1].code.contains("str"));
    }

    #[test]
    fn block_comments_span_lines_and_nest() {
        let src = "a /* x\n /* y */ still comment\n*/ b\n";
        let file = scan("f.rs", src);
        assert!(file.lines[0].code.contains('a'));
        assert!(!file.lines[1].code.contains("still"));
        assert!(file.lines[2].code.contains('b'));
    }

    #[test]
    fn raw_strings_are_blanked() {
        let src = "let t = r#\"for x in map.iter()\"#; map.keys();\n";
        let file = scan("f.rs", src);
        assert!(!file.lines[0].code.contains("iter"));
        assert!(file.lines[0].code.contains("keys"));
    }

    #[test]
    fn tracks_enclosing_fn_across_multiline_signatures() {
        let src = "\
fn outer(\n\
    x: usize,\n\
) -> usize {\n\
    let y = x;\n\
    y\n\
}\n\
fn second() {\n\
    1;\n\
}\n";
        let file = scan("f.rs", src);
        assert_eq!(file.lines[3].enclosing_fn.as_deref(), Some("outer"));
        assert_eq!(file.lines[7].enclosing_fn.as_deref(), Some("second"));
        assert_eq!(file.lines[6].enclosing_fn, None);
    }

    #[test]
    fn trait_method_declarations_do_not_capture_scope() {
        let src = "\
trait T {\n\
    fn decl(&self, xs: [u8; 4]) -> u8;\n\
}\n\
fn real() {\n\
    2;\n\
}\n";
        let file = scan("f.rs", src);
        assert_eq!(file.lines[4].enclosing_fn.as_deref(), Some("real"));
    }

    #[test]
    fn parses_pragmas_with_and_without_reason() {
        let src = "\
// lint: allow(unordered-iter, \"sorted right below\")\n\
let x = 1; // lint: allow(wall-clock)\n";
        let file = scan("f.rs", src);
        assert_eq!(file.pragmas.len(), 2);
        assert_eq!(file.pragmas[0].rule, "unordered-iter");
        assert_eq!(file.pragmas[0].reason.as_deref(), Some("sorted right below"));
        assert_eq!(file.pragmas[1].line, 2);
        assert_eq!(file.pragmas[1].rule, "wall-clock");
        assert!(file.pragmas[1].reason.is_none());
    }
}
