//! Service metrics: lock-free counters plus a bucketed latency
//! histogram with approximate quantiles.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::engine::CacheStats;

/// Log-spaced latency buckets from 10 µs up: 32 log₂ buckets, so the
/// last one starts at 10 µs · 2³¹ ≈ 2×10⁴ s (anything slower clamps
/// into it).
const BUCKET_COUNT: usize = 32;

fn bucket_for(d: Duration) -> usize {
    let us = d.as_micros().max(1) as f64;
    // bucket = log2(us / 10), clamped.
    let b = (us / 10.0).log2().floor();
    b.clamp(0.0, (BUCKET_COUNT - 1) as f64) as usize
}

fn bucket_upper_us(b: usize) -> f64 {
    10.0 * 2f64.powi(b as i32 + 1)
}

/// Thread-safe latency histogram.
#[derive(Debug)]
pub struct LatencyHistogram {
    buckets: [AtomicU64; BUCKET_COUNT],
    count: AtomicU64,
    total_us: AtomicU64,
    max_us: AtomicU64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    /// An empty histogram.
    pub fn new() -> Self {
        LatencyHistogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            total_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }

    /// Record one latency sample.
    pub fn record(&self, d: Duration) {
        let us = d.as_micros() as u64;
        self.buckets[bucket_for(d)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.total_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    /// Number of recorded samples.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Mean latency.
    pub fn mean(&self) -> Duration {
        let c = self.count();
        if c == 0 {
            return Duration::ZERO;
        }
        Duration::from_micros(self.total_us.load(Ordering::Relaxed) / c)
    }

    /// Approximate quantile from the bucket upper bounds (q in [0,1]).
    pub fn quantile(&self, q: f64) -> Duration {
        let c = self.count();
        if c == 0 {
            return Duration::ZERO;
        }
        // Floor the target at 1 sample and skip empty buckets: with a
        // target of 0, `seen >= target` held at bucket 0 even when that
        // bucket was empty, so q = 0 reported 20 µs regardless of the
        // recorded data.
        let target = ((q.clamp(0.0, 1.0) * c as f64).ceil() as u64).max(1);
        let mut seen = 0;
        for (b, bucket) in self.buckets.iter().enumerate() {
            let in_bucket = bucket.load(Ordering::Relaxed);
            if in_bucket == 0 {
                continue;
            }
            seen += in_bucket;
            if seen >= target {
                return Duration::from_micros(bucket_upper_us(b) as u64);
            }
        }
        Duration::from_micros(self.max_us.load(Ordering::Relaxed))
    }

    /// Largest recorded latency.
    pub fn max(&self) -> Duration {
        Duration::from_micros(self.max_us.load(Ordering::Relaxed))
    }

    /// Fold another histogram's samples into this one (bucket-wise).
    ///
    /// The cross-shard merge: each shard records the latency of the jobs
    /// its workers executed into its own histogram, and the service
    /// snapshot merges them into one service-wide distribution — the
    /// same quantiles the single-queue design reported from its single
    /// histogram.
    pub fn absorb(&self, other: &LatencyHistogram) {
        for (mine, theirs) in self.buckets.iter().zip(&other.buckets) {
            mine.fetch_add(theirs.load(Ordering::Relaxed), Ordering::Relaxed);
        }
        self.count.fetch_add(other.count.load(Ordering::Relaxed), Ordering::Relaxed);
        self.total_us.fetch_add(other.total_us.load(Ordering::Relaxed), Ordering::Relaxed);
        self.max_us.fetch_max(other.max_us.load(Ordering::Relaxed), Ordering::Relaxed);
    }
}

/// Point-in-time gauges of one shard of the sharded worker pool
/// (surfaced in [`MetricsSnapshot::shards`]).
///
/// Attribution: `depth`, `routed`, `queued_max` and `stolen_from`
/// describe the shard's QUEUE (its home batches); `busy`, `stolen`,
/// `completed`, `failed` and `p99_latency` describe the shard's WORKERS
/// (including batches they stole from other shards). Summing
/// `completed`/`failed` across shards therefore reproduces the global
/// counters exactly, whether or not stealing moved work.
#[derive(Clone, Debug)]
pub struct ShardStats {
    /// Shard index (0-based).
    pub shard: usize,
    /// Batches currently queued on this shard.
    pub depth: usize,
    /// Peak queue depth observed since start.
    pub queued_max: u64,
    /// This shard's workers currently executing a batch.
    pub busy: u64,
    /// Batches the scheduler routed to this shard.
    pub routed: u64,
    /// Batches this shard's workers stole from other shards.
    pub stolen: u64,
    /// Batches other shards' workers stole from this queue.
    pub stolen_from: u64,
    /// Jobs completed by this shard's workers.
    pub completed: u64,
    /// Jobs failed on this shard's workers.
    pub failed: u64,
    /// 99th-percentile latency of jobs executed by this shard's workers
    /// (bucket upper bound).
    pub p99_latency: Duration,
}

impl ShardStats {
    /// One-line rendering (one per shard in
    /// [`MetricsSnapshot::render`]).
    pub fn render(&self) -> String {
        format!(
            "shard {}: depth {} (max {})  busy {}  routed {}  stolen {} (lost {})  \
             completed {}  failed {}  p99 {:.1?}",
            self.shard,
            self.depth,
            self.queued_max,
            self.busy,
            self.routed,
            self.stolen,
            self.stolen_from,
            self.completed,
            self.failed,
            self.p99_latency
        )
    }
}

/// Per-backend counters of the multi-process balancer
/// ([`crate::net`] `Balancer`), surfaced both on the balancer's own
/// `/metrics` page ([`render_balancer_prometheus`]) and in
/// [`MetricsSnapshot::balancer`] (empty for a plain single-process
/// coordinator — the families still render headers-only, so the
/// exposition's family set is scrape-stable either way).
#[derive(Clone, Debug)]
pub struct BalancerBackendStats {
    /// Backend index (0-based, the affinity modulus position).
    pub backend: usize,
    /// The backend's address as configured (`host:port`).
    pub addr: String,
    /// Whether the balancer currently routes to this backend.
    pub healthy: bool,
    /// Jobs routed here by fingerprint affinity (home slot).
    pub routed_affine: u64,
    /// Fingerprint-less or failed-over jobs routed here round-robin.
    pub routed_round_robin: u64,
    /// Proxied requests this backend answered with a 2xx.
    pub completed: u64,
    /// Proxied requests retried after this backend answered 429/503 or
    /// failed at the socket level.
    pub retried: u64,
    /// Health transitions healthy → evicted (failed probe, proxied 503,
    /// or IO error).
    pub evictions: u64,
    /// Health transitions evicted → healthy (a `/healthz` probe
    /// succeeded again).
    pub readmissions: u64,
}

impl BalancerBackendStats {
    /// One-line rendering (one per backend in the `balance` summary).
    pub fn render(&self) -> String {
        format!(
            "backend {} ({}): {}  affine {}  round-robin {}  completed {}  retried {}  \
             evicted {}  readmitted {}",
            self.backend,
            self.addr,
            if self.healthy { "healthy" } else { "evicted" },
            self.routed_affine,
            self.routed_round_robin,
            self.completed,
            self.retried,
            self.evictions,
            self.readmissions
        )
    }
}

/// Point-in-time snapshot of service metrics.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    /// Jobs accepted into the queue.
    pub submitted: u64,
    /// Jobs completed successfully.
    pub completed: u64,
    /// Jobs that came back with a per-job error.
    pub failed: u64,
    /// Batches flushed by the batcher.
    pub batches: u64,
    /// Mean end-to-end job latency (queue + solve).
    pub mean_latency: Duration,
    /// Median end-to-end job latency (bucket upper bound).
    pub p50_latency: Duration,
    /// 99th-percentile end-to-end job latency (bucket upper bound).
    pub p99_latency: Duration,
    /// Largest observed end-to-end job latency.
    pub max_latency: Duration,
    /// Jobs per second over the service lifetime.
    pub throughput: f64,
    /// Per-method log-domain escalation counters: completed jobs —
    /// distance and barycenter jobs alike — whose solution reports
    /// `BackendKind::LogDomain` although neither the method
    /// (`spar-sink-log`) nor the job's `ProblemSpec::backend` forced the
    /// log engine — i.e. the `Auto` policy escalated, either up front
    /// (small ε) or after a multiplicative failure/collapse. Only
    /// methods with a non-zero count appear.
    pub log_escalations: Vec<(&'static str, u64)>,
    /// Gauge: escalated jobs / completed jobs.
    pub log_escalation_rate: f64,
    /// Per-shard gauges of the sharded worker pool, one entry per
    /// shard. Queue-side gauges (`depth`, `routed`, `stolen_from`)
    /// describe each shard's home queue; worker-side counters (`busy`,
    /// `stolen`, `completed`, `failed`, `p99_latency`) describe the
    /// batches its workers actually executed, so the per-shard
    /// completed/failed counts sum to the global counters above. The
    /// service-wide latency quantiles are the cross-shard
    /// [`LatencyHistogram`] merge.
    pub shards: Vec<ShardStats>,
    /// Shared-cost artifact cache counters/gauges: hits, misses,
    /// evictions, resident entries/bytes, in-flight builds (the
    /// `building` gauge — single-flight slots under construction), and
    /// the byte budget. A pairwise run over T frames on one shared
    /// support shows exactly one miss per (η, ε, formulation) and hits
    /// for every other job — including jobs that arrived while the
    /// build was in flight and blocked on its slot.
    pub cache: CacheStats,
    /// Per-backend balancer counters — populated only when the snapshot
    /// comes from a multi-process `Balancer`; a plain coordinator
    /// leaves it empty and the balancer families render headers-only.
    pub balancer: Vec<BalancerBackendStats>,
}

impl MetricsSnapshot {
    /// Multi-line human-readable rendering (the `serve` summary).
    pub fn render(&self) -> String {
        let escalations = if self.log_escalations.is_empty() {
            "none".to_string()
        } else {
            self.log_escalations
                .iter()
                .map(|(method, count)| format!("{method}={count}"))
                .collect::<Vec<_>>()
                .join(" ")
        };
        let mut out = format!(
            "jobs: {} submitted / {} completed / {} failed in {} batches\n\
             latency: mean {:.1?}  p50 {:.1?}  p99 {:.1?}  max {:.1?}\n\
             throughput: {:.2} jobs/s\n\
             log-domain escalations: {} (rate {:.3})\n\
             artifact cache: {}",
            self.submitted,
            self.completed,
            self.failed,
            self.batches,
            self.mean_latency,
            self.p50_latency,
            self.p99_latency,
            self.max_latency,
            self.throughput,
            escalations,
            self.log_escalation_rate,
            self.cache.render()
        );
        for shard in &self.shards {
            out.push('\n');
            out.push_str(&shard.render());
        }
        out
    }

    /// Prometheus text-exposition rendering of the snapshot (what the
    /// gateway's `/metrics` endpoint serves).
    ///
    /// Conventions: every family is prefixed `spar_sink_`, counters end
    /// in `_total` and gauges do not, durations are seconds
    /// (`f64::to_string` — shortest round-trip, so scrapes preserve the
    /// exact values), per-shard samples carry a `{shard="i"}` label,
    /// per-method escalation counters a `{method="name"}` label, and
    /// latency stats a `{stat="…"}` label. Output is deterministic for
    /// a given snapshot — fixed family order, shards in index order,
    /// escalations in registry order — and pinned verbatim by the
    /// golden test below.
    pub fn render_prometheus(&self) -> String {
        let mut out = String::new();
        counter_family(
            &mut out,
            "spar_sink_jobs_submitted_total",
            "Jobs accepted into the submission queue.",
            &[(String::new(), self.submitted as f64)],
        );
        counter_family(
            &mut out,
            "spar_sink_jobs_completed_total",
            "Jobs completed successfully.",
            &[(String::new(), self.completed as f64)],
        );
        counter_family(
            &mut out,
            "spar_sink_jobs_failed_total",
            "Jobs that returned a per-job error.",
            &[(String::new(), self.failed as f64)],
        );
        counter_family(
            &mut out,
            "spar_sink_batches_total",
            "Batches flushed by the batcher.",
            &[(String::new(), self.batches as f64)],
        );
        gauge_family(
            &mut out,
            "spar_sink_job_latency_seconds",
            "End-to-end job latency (queue + solve); quantiles are histogram bucket upper bounds.",
            &[
                ("{stat=\"mean\"}".to_string(), self.mean_latency.as_secs_f64()),
                ("{stat=\"p50\"}".to_string(), self.p50_latency.as_secs_f64()),
                ("{stat=\"p99\"}".to_string(), self.p99_latency.as_secs_f64()),
                ("{stat=\"max\"}".to_string(), self.max_latency.as_secs_f64()),
            ],
        );
        gauge_family(
            &mut out,
            "spar_sink_throughput_jobs_per_second",
            "Completed jobs per second over the service lifetime.",
            &[(String::new(), self.throughput)],
        );
        let escalations: Vec<(String, f64)> = self
            .log_escalations
            .iter()
            .map(|(method, count)| (format!("{{method=\"{method}\"}}"), *count as f64))
            .collect();
        counter_family(
            &mut out,
            "spar_sink_log_escalations_total",
            "Completed jobs the Auto policy escalated to the log-domain engine, by method.",
            &escalations,
        );
        gauge_family(
            &mut out,
            "spar_sink_log_escalation_rate",
            "Escalated jobs / completed jobs.",
            &[(String::new(), self.log_escalation_rate)],
        );

        let shard_samples = |value: fn(&ShardStats) -> f64| -> Vec<(String, f64)> {
            self.shards
                .iter()
                .map(|s| (format!("{{shard=\"{}\"}}", s.shard), value(s)))
                .collect()
        };
        gauge_family(
            &mut out,
            "spar_sink_shard_depth",
            "Batches currently queued on the shard.",
            &shard_samples(|s| s.depth as f64),
        );
        gauge_family(
            &mut out,
            "spar_sink_shard_queued_max",
            "Peak queue depth observed on the shard since start.",
            &shard_samples(|s| s.queued_max as f64),
        );
        gauge_family(
            &mut out,
            "spar_sink_shard_busy",
            "Workers of the shard currently executing a batch.",
            &shard_samples(|s| s.busy as f64),
        );
        counter_family(
            &mut out,
            "spar_sink_shard_routed_total",
            "Batches the scheduler routed to the shard.",
            &shard_samples(|s| s.routed as f64),
        );
        counter_family(
            &mut out,
            "spar_sink_shard_stolen_total",
            "Batches the shard's workers stole from other shards.",
            &shard_samples(|s| s.stolen as f64),
        );
        counter_family(
            &mut out,
            "spar_sink_shard_stolen_from_total",
            "Batches other shards' workers stole from this shard's queue.",
            &shard_samples(|s| s.stolen_from as f64),
        );
        counter_family(
            &mut out,
            "spar_sink_shard_completed_total",
            "Jobs completed by the shard's workers.",
            &shard_samples(|s| s.completed as f64),
        );
        counter_family(
            &mut out,
            "spar_sink_shard_failed_total",
            "Jobs failed on the shard's workers.",
            &shard_samples(|s| s.failed as f64),
        );
        gauge_family(
            &mut out,
            "spar_sink_shard_p99_latency_seconds",
            "99th-percentile latency of jobs executed by the shard's workers.",
            &shard_samples(|s| s.p99_latency.as_secs_f64()),
        );

        counter_family(
            &mut out,
            "spar_sink_cache_hits_total",
            "Artifact-cache lookups served from a resident or in-flight build.",
            &[(String::new(), self.cache.hits as f64)],
        );
        counter_family(
            &mut out,
            "spar_sink_cache_misses_total",
            "Artifact-cache lookups that had to build.",
            &[(String::new(), self.cache.misses as f64)],
        );
        counter_family(
            &mut out,
            "spar_sink_cache_evictions_total",
            "Artifacts dropped to respect the byte budget.",
            &[(String::new(), self.cache.evictions as f64)],
        );
        gauge_family(
            &mut out,
            "spar_sink_cache_entries",
            "Resident artifacts.",
            &[(String::new(), self.cache.entries as f64)],
        );
        gauge_family(
            &mut out,
            "spar_sink_cache_building",
            "In-flight single-flight artifact builds.",
            &[(String::new(), self.cache.building as f64)],
        );
        gauge_family(
            &mut out,
            "spar_sink_cache_bytes",
            "Resident artifact bytes.",
            &[(String::new(), self.cache.bytes as f64)],
        );
        gauge_family(
            &mut out,
            "spar_sink_cache_byte_budget_bytes",
            "Configured artifact-cache byte budget.",
            &[(String::new(), self.cache.byte_budget as f64)],
        );
        balancer_families(&mut out, &self.balancer);
        out
    }
}

/// Prometheus rendering of just the balancer families — what the
/// balancer's own `/metrics` endpoint serves (it has no coordinator of
/// its own, so the full [`MetricsSnapshot`] exposition would be all
/// zeros). Same family names, kinds and `{backend="i"}` labels as the
/// tail of [`MetricsSnapshot::render_prometheus`], pinned by the same
/// golden test.
pub fn render_balancer_prometheus(backends: &[BalancerBackendStats]) -> String {
    let mut out = String::new();
    balancer_families(&mut out, backends);
    out
}

/// The balancer family block shared by [`render_balancer_prometheus`]
/// and the snapshot exposition. Every family renders its HELP/TYPE
/// headers even with no backends, keeping the exposition scrape-stable.
fn balancer_families(out: &mut String, backends: &[BalancerBackendStats]) {
    gauge_family(
        out,
        "spar_sink_balancer_backend_healthy",
        "Whether the balancer currently routes to the backend (1) or has evicted it (0).",
        &backends
            .iter()
            .map(|b| {
                (
                    format!("{{backend=\"{}\",addr=\"{}\"}}", b.backend, b.addr),
                    if b.healthy { 1.0 } else { 0.0 },
                )
            })
            .collect::<Vec<_>>(),
    );
    let backend_samples = |value: fn(&BalancerBackendStats) -> f64| -> Vec<(String, f64)> {
        backends
            .iter()
            .map(|b| (format!("{{backend=\"{}\"}}", b.backend), value(b)))
            .collect()
    };
    counter_family(
        out,
        "spar_sink_balancer_affine_routed_total",
        "Jobs the balancer routed to the backend by fingerprint affinity (home slot).",
        &backend_samples(|b| b.routed_affine as f64),
    );
    counter_family(
        out,
        "spar_sink_balancer_round_robin_routed_total",
        "Fingerprint-less or failed-over jobs the balancer routed to the backend round-robin.",
        &backend_samples(|b| b.routed_round_robin as f64),
    );
    counter_family(
        out,
        "spar_sink_balancer_completed_total",
        "Proxied requests the backend answered with a 2xx.",
        &backend_samples(|b| b.completed as f64),
    );
    counter_family(
        out,
        "spar_sink_balancer_retries_total",
        "Proxied requests retried after the backend answered 429/503 or failed at the socket.",
        &backend_samples(|b| b.retried as f64),
    );
    counter_family(
        out,
        "spar_sink_balancer_evictions_total",
        "Health transitions healthy -> evicted (failed probe, proxied 503, or IO error).",
        &backend_samples(|b| b.evictions as f64),
    );
    counter_family(
        out,
        "spar_sink_balancer_readmissions_total",
        "Health transitions evicted -> healthy (a /healthz probe succeeded again).",
        &backend_samples(|b| b.readmissions as f64),
    );
}

/// Append one `# HELP`/`# TYPE` header plus one sample line per
/// `(labels, value)` pair; `labels` is either empty or a pre-rendered
/// `{name="value"}` block. A family with no samples still renders its
/// headers, so the exposition's shape is scrape-stable.
fn prom_family(out: &mut String, name: &str, kind: &str, help: &str, samples: &[(String, f64)]) {
    out.push_str("# HELP ");
    out.push_str(name);
    out.push(' ');
    out.push_str(help);
    out.push_str("\n# TYPE ");
    out.push_str(name);
    out.push(' ');
    out.push_str(kind);
    out.push('\n');
    for (labels, value) in samples {
        out.push_str(name);
        out.push_str(labels);
        out.push(' ');
        out.push_str(&prom_value(*value));
        out.push('\n');
    }
}

fn counter_family(out: &mut String, name: &str, help: &str, samples: &[(String, f64)]) {
    prom_family(out, name, "counter", help, samples);
}

fn gauge_family(out: &mut String, name: &str, help: &str, samples: &[(String, f64)]) {
    prom_family(out, name, "gauge", help, samples);
}

/// Prometheus sample formatting: integers without a trailing `.0`
/// (counter idiom), everything else via `f64`'s shortest round-trip
/// `Display`, non-finite as the spec's literals.
fn prom_value(x: f64) -> String {
    if x.is_nan() {
        "NaN".to_string()
    } else if x == f64::INFINITY {
        "+Inf".to_string()
    } else if x == f64::NEG_INFINITY {
        "-Inf".to_string()
    } else if x == x.trunc() && x.abs() < 1e15 {
        format!("{}", x as i64)
    } else {
        format!("{x}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_counts_and_mean() {
        let h = LatencyHistogram::new();
        h.record(Duration::from_millis(1));
        h.record(Duration::from_millis(3));
        assert_eq!(h.count(), 2);
        let mean = h.mean();
        assert!(mean >= Duration::from_millis(1) && mean <= Duration::from_millis(3));
    }

    #[test]
    fn quantiles_are_monotone() {
        let h = LatencyHistogram::new();
        for i in 1..=100u64 {
            h.record(Duration::from_micros(i * 100));
        }
        let p50 = h.quantile(0.5);
        let p99 = h.quantile(0.99);
        assert!(p50 <= p99, "{p50:?} vs {p99:?}");
        assert!(p99 <= h.max() * 4, "bucket upper bound sanity");
    }

    #[test]
    fn empty_histogram() {
        let h = LatencyHistogram::new();
        assert_eq!(h.mean(), Duration::ZERO);
        assert_eq!(h.quantile(0.9), Duration::ZERO);
        assert_eq!(h.quantile(0.0), Duration::ZERO);
    }

    #[test]
    fn quantile_zero_skips_empty_buckets() {
        // A single 1 s sample: every quantile, including q = 0, must
        // land in that sample's bucket — not report bucket 0's 20 µs
        // upper bound just because the target rounded down to 0.
        let h = LatencyHistogram::new();
        h.record(Duration::from_secs(1));
        let q0 = h.quantile(0.0);
        assert!(q0 >= Duration::from_secs(1), "q0 {q0:?}");
        assert_eq!(q0, h.quantile(0.5));
        assert_eq!(q0, h.quantile(1.0));
    }

    #[test]
    fn absorb_merges_bucketwise() {
        let a = LatencyHistogram::new();
        let b = LatencyHistogram::new();
        a.record(Duration::from_micros(100));
        b.record(Duration::from_millis(10));
        b.record(Duration::from_millis(20));
        let merged = LatencyHistogram::new();
        merged.absorb(&a);
        merged.absorb(&b);
        assert_eq!(merged.count(), 3);
        assert_eq!(merged.max(), b.max());
        // Mean of the merge is the pooled mean, not the mean of means
        // (integer-µs division, matching `mean()`).
        assert_eq!(merged.mean(), Duration::from_micros((100 + 10_000 + 20_000) / 3));
        // Quantiles span both sources: p0 from `a`, p100 from `b`.
        assert!(merged.quantile(0.0) <= Duration::from_micros(400));
        assert!(merged.quantile(1.0) >= Duration::from_millis(10));
    }

    #[test]
    fn shard_stats_render_one_line_each() {
        let s = ShardStats {
            shard: 3,
            depth: 2,
            queued_max: 5,
            busy: 1,
            routed: 7,
            stolen: 4,
            stolen_from: 2,
            completed: 40,
            failed: 1,
            p99_latency: Duration::from_millis(3),
        };
        let line = s.render();
        assert!(line.starts_with("shard 3:"), "{line}");
        assert!(line.contains("routed 7"), "{line}");
        assert!(line.contains("stolen 4 (lost 2)"), "{line}");
        assert!(!line.contains('\n'), "{line}");
    }

    fn synthetic_snapshot() -> MetricsSnapshot {
        MetricsSnapshot {
            submitted: 8,
            completed: 7,
            failed: 1,
            batches: 3,
            mean_latency: Duration::from_micros(1500),
            p50_latency: Duration::from_micros(1280),
            p99_latency: Duration::from_micros(5120),
            max_latency: Duration::from_millis(6),
            throughput: 123.5,
            log_escalations: vec![("spar-sink", 2)],
            log_escalation_rate: 0.25,
            shards: vec![
                ShardStats {
                    shard: 0,
                    depth: 2,
                    queued_max: 5,
                    busy: 1,
                    routed: 4,
                    stolen: 3,
                    stolen_from: 1,
                    completed: 6,
                    failed: 1,
                    p99_latency: Duration::from_millis(4),
                },
                ShardStats {
                    shard: 1,
                    depth: 0,
                    queued_max: 2,
                    busy: 0,
                    routed: 2,
                    stolen: 0,
                    stolen_from: 3,
                    completed: 1,
                    failed: 0,
                    p99_latency: Duration::from_micros(500),
                },
            ],
            cache: CacheStats {
                hits: 10,
                misses: 2,
                evictions: 1,
                entries: 1,
                building: 1,
                bytes: 2048,
                byte_budget: 4096,
            },
            balancer: vec![
                BalancerBackendStats {
                    backend: 0,
                    addr: "127.0.0.1:9101".to_string(),
                    healthy: true,
                    routed_affine: 5,
                    routed_round_robin: 1,
                    completed: 6,
                    retried: 1,
                    evictions: 0,
                    readmissions: 0,
                },
                BalancerBackendStats {
                    backend: 1,
                    addr: "127.0.0.1:9102".to_string(),
                    healthy: false,
                    routed_affine: 2,
                    routed_round_robin: 0,
                    completed: 1,
                    retried: 0,
                    evictions: 1,
                    readmissions: 1,
                },
            ],
        }
    }

    /// The golden: the full exposition for a synthetic snapshot, pinned
    /// verbatim. Metric naming (`spar_sink_` prefix, `_total` suffix on
    /// counters), `# HELP`/`# TYPE` lines, counter-vs-gauge kinds,
    /// per-shard `{shard="i"}` and per-method `{method="…"}` labels,
    /// and second-unit duration formatting are all load-bearing for
    /// scrapers — any change here is a dashboard-breaking change.
    #[test]
    fn prometheus_rendering_matches_the_golden() {
        let expected = r#"# HELP spar_sink_jobs_submitted_total Jobs accepted into the submission queue.
# TYPE spar_sink_jobs_submitted_total counter
spar_sink_jobs_submitted_total 8
# HELP spar_sink_jobs_completed_total Jobs completed successfully.
# TYPE spar_sink_jobs_completed_total counter
spar_sink_jobs_completed_total 7
# HELP spar_sink_jobs_failed_total Jobs that returned a per-job error.
# TYPE spar_sink_jobs_failed_total counter
spar_sink_jobs_failed_total 1
# HELP spar_sink_batches_total Batches flushed by the batcher.
# TYPE spar_sink_batches_total counter
spar_sink_batches_total 3
# HELP spar_sink_job_latency_seconds End-to-end job latency (queue + solve); quantiles are histogram bucket upper bounds.
# TYPE spar_sink_job_latency_seconds gauge
spar_sink_job_latency_seconds{stat="mean"} 0.0015
spar_sink_job_latency_seconds{stat="p50"} 0.00128
spar_sink_job_latency_seconds{stat="p99"} 0.00512
spar_sink_job_latency_seconds{stat="max"} 0.006
# HELP spar_sink_throughput_jobs_per_second Completed jobs per second over the service lifetime.
# TYPE spar_sink_throughput_jobs_per_second gauge
spar_sink_throughput_jobs_per_second 123.5
# HELP spar_sink_log_escalations_total Completed jobs the Auto policy escalated to the log-domain engine, by method.
# TYPE spar_sink_log_escalations_total counter
spar_sink_log_escalations_total{method="spar-sink"} 2
# HELP spar_sink_log_escalation_rate Escalated jobs / completed jobs.
# TYPE spar_sink_log_escalation_rate gauge
spar_sink_log_escalation_rate 0.25
# HELP spar_sink_shard_depth Batches currently queued on the shard.
# TYPE spar_sink_shard_depth gauge
spar_sink_shard_depth{shard="0"} 2
spar_sink_shard_depth{shard="1"} 0
# HELP spar_sink_shard_queued_max Peak queue depth observed on the shard since start.
# TYPE spar_sink_shard_queued_max gauge
spar_sink_shard_queued_max{shard="0"} 5
spar_sink_shard_queued_max{shard="1"} 2
# HELP spar_sink_shard_busy Workers of the shard currently executing a batch.
# TYPE spar_sink_shard_busy gauge
spar_sink_shard_busy{shard="0"} 1
spar_sink_shard_busy{shard="1"} 0
# HELP spar_sink_shard_routed_total Batches the scheduler routed to the shard.
# TYPE spar_sink_shard_routed_total counter
spar_sink_shard_routed_total{shard="0"} 4
spar_sink_shard_routed_total{shard="1"} 2
# HELP spar_sink_shard_stolen_total Batches the shard's workers stole from other shards.
# TYPE spar_sink_shard_stolen_total counter
spar_sink_shard_stolen_total{shard="0"} 3
spar_sink_shard_stolen_total{shard="1"} 0
# HELP spar_sink_shard_stolen_from_total Batches other shards' workers stole from this shard's queue.
# TYPE spar_sink_shard_stolen_from_total counter
spar_sink_shard_stolen_from_total{shard="0"} 1
spar_sink_shard_stolen_from_total{shard="1"} 3
# HELP spar_sink_shard_completed_total Jobs completed by the shard's workers.
# TYPE spar_sink_shard_completed_total counter
spar_sink_shard_completed_total{shard="0"} 6
spar_sink_shard_completed_total{shard="1"} 1
# HELP spar_sink_shard_failed_total Jobs failed on the shard's workers.
# TYPE spar_sink_shard_failed_total counter
spar_sink_shard_failed_total{shard="0"} 1
spar_sink_shard_failed_total{shard="1"} 0
# HELP spar_sink_shard_p99_latency_seconds 99th-percentile latency of jobs executed by the shard's workers.
# TYPE spar_sink_shard_p99_latency_seconds gauge
spar_sink_shard_p99_latency_seconds{shard="0"} 0.004
spar_sink_shard_p99_latency_seconds{shard="1"} 0.0005
# HELP spar_sink_cache_hits_total Artifact-cache lookups served from a resident or in-flight build.
# TYPE spar_sink_cache_hits_total counter
spar_sink_cache_hits_total 10
# HELP spar_sink_cache_misses_total Artifact-cache lookups that had to build.
# TYPE spar_sink_cache_misses_total counter
spar_sink_cache_misses_total 2
# HELP spar_sink_cache_evictions_total Artifacts dropped to respect the byte budget.
# TYPE spar_sink_cache_evictions_total counter
spar_sink_cache_evictions_total 1
# HELP spar_sink_cache_entries Resident artifacts.
# TYPE spar_sink_cache_entries gauge
spar_sink_cache_entries 1
# HELP spar_sink_cache_building In-flight single-flight artifact builds.
# TYPE spar_sink_cache_building gauge
spar_sink_cache_building 1
# HELP spar_sink_cache_bytes Resident artifact bytes.
# TYPE spar_sink_cache_bytes gauge
spar_sink_cache_bytes 2048
# HELP spar_sink_cache_byte_budget_bytes Configured artifact-cache byte budget.
# TYPE spar_sink_cache_byte_budget_bytes gauge
spar_sink_cache_byte_budget_bytes 4096
# HELP spar_sink_balancer_backend_healthy Whether the balancer currently routes to the backend (1) or has evicted it (0).
# TYPE spar_sink_balancer_backend_healthy gauge
spar_sink_balancer_backend_healthy{backend="0",addr="127.0.0.1:9101"} 1
spar_sink_balancer_backend_healthy{backend="1",addr="127.0.0.1:9102"} 0
# HELP spar_sink_balancer_affine_routed_total Jobs the balancer routed to the backend by fingerprint affinity (home slot).
# TYPE spar_sink_balancer_affine_routed_total counter
spar_sink_balancer_affine_routed_total{backend="0"} 5
spar_sink_balancer_affine_routed_total{backend="1"} 2
# HELP spar_sink_balancer_round_robin_routed_total Fingerprint-less or failed-over jobs the balancer routed to the backend round-robin.
# TYPE spar_sink_balancer_round_robin_routed_total counter
spar_sink_balancer_round_robin_routed_total{backend="0"} 1
spar_sink_balancer_round_robin_routed_total{backend="1"} 0
# HELP spar_sink_balancer_completed_total Proxied requests the backend answered with a 2xx.
# TYPE spar_sink_balancer_completed_total counter
spar_sink_balancer_completed_total{backend="0"} 6
spar_sink_balancer_completed_total{backend="1"} 1
# HELP spar_sink_balancer_retries_total Proxied requests retried after the backend answered 429/503 or failed at the socket.
# TYPE spar_sink_balancer_retries_total counter
spar_sink_balancer_retries_total{backend="0"} 1
spar_sink_balancer_retries_total{backend="1"} 0
# HELP spar_sink_balancer_evictions_total Health transitions healthy -> evicted (failed probe, proxied 503, or IO error).
# TYPE spar_sink_balancer_evictions_total counter
spar_sink_balancer_evictions_total{backend="0"} 0
spar_sink_balancer_evictions_total{backend="1"} 1
# HELP spar_sink_balancer_readmissions_total Health transitions evicted -> healthy (a /healthz probe succeeded again).
# TYPE spar_sink_balancer_readmissions_total counter
spar_sink_balancer_readmissions_total{backend="0"} 0
spar_sink_balancer_readmissions_total{backend="1"} 1
"#;
        let rendered = synthetic_snapshot().render_prometheus();
        // On mismatch, point at the first diverging line instead of
        // dumping two 90-line blobs.
        for (i, (got, want)) in rendered.lines().zip(expected.lines()).enumerate() {
            assert_eq!(got, want, "first divergence at exposition line {}", i + 1);
        }
        assert_eq!(rendered, expected);
    }

    #[test]
    fn prometheus_rendering_with_no_shards_or_escalations_keeps_headers() {
        // Empty per-shard/per-method families still emit HELP/TYPE so
        // the exposition's family set is scrape-stable from the first
        // request on.
        let snapshot = MetricsSnapshot {
            shards: Vec::new(),
            log_escalations: Vec::new(),
            balancer: Vec::new(),
            ..synthetic_snapshot()
        };
        let text = snapshot.render_prometheus();
        assert!(text.contains("# TYPE spar_sink_shard_depth gauge\n# HELP"), "{text}");
        assert!(
            text.contains("# TYPE spar_sink_log_escalations_total counter\n# HELP"),
            "{text}"
        );
        assert!(!text.contains("{shard="), "{text}");
        // Balancer families behave the same: a coordinator with no
        // balancer keeps the headers but emits no samples.
        assert!(
            text.contains("# TYPE spar_sink_balancer_backend_healthy gauge\n# HELP"),
            "{text}"
        );
        assert!(!text.contains("{backend="), "{text}");
    }

    #[test]
    fn balancer_metrics_page_is_the_snapshot_tail() {
        // The balancer's own /metrics page and the snapshot exposition
        // render the SAME family block — one source of truth, so the
        // golden above pins both.
        let snapshot = synthetic_snapshot();
        let page = render_balancer_prometheus(&snapshot.balancer);
        assert!(snapshot.render_prometheus().ends_with(&page));
        assert!(page.starts_with("# HELP spar_sink_balancer_backend_healthy"));
        assert!(page.contains("spar_sink_balancer_readmissions_total{backend=\"1\"} 1\n"));
    }

    #[test]
    fn balancer_backend_stats_render_one_line_each() {
        let line = synthetic_snapshot().balancer[1].render();
        assert!(line.starts_with("backend 1 (127.0.0.1:9102): evicted"), "{line}");
        assert!(line.contains("readmitted 1"), "{line}");
        assert!(!line.contains('\n'), "{line}");
    }

    #[test]
    fn prometheus_values_format_like_the_spec() {
        assert_eq!(prom_value(0.0), "0");
        assert_eq!(prom_value(42.0), "42");
        assert_eq!(prom_value(0.0015), "0.0015");
        assert_eq!(prom_value(123.5), "123.5");
        assert_eq!(prom_value(f64::NAN), "NaN");
        assert_eq!(prom_value(f64::INFINITY), "+Inf");
        assert_eq!(prom_value(f64::NEG_INFINITY), "-Inf");
        // Above the exact-integer window the float path takes over.
        assert_eq!(prom_value(1e18), "1000000000000000000");
    }

    #[test]
    fn bucket_mapping_monotone() {
        let mut prev = 0;
        for ms in [1u64, 2, 5, 10, 100, 1000, 10_000] {
            let b = bucket_for(Duration::from_millis(ms));
            assert!(b >= prev);
            prev = b;
        }
    }
}
