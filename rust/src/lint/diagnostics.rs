//! Finding type and rendering for the contract-lint pass.

use std::fmt;

/// One lint finding: a contract violation at a specific source line.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// Path of the offending file, relative to the lint root.
    pub path: String,
    /// 1-based line number.
    pub line: usize,
    /// Rule id (one of [`super::rules::RULES`]).
    pub rule: &'static str,
    /// Human-readable explanation of the violation.
    pub message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.path, self.line, self.rule, self.message)
    }
}

/// Order findings for stable output: by path, then line, then rule id.
pub fn sort_findings(findings: &mut [Finding]) {
    findings.sort_by(|a, b| {
        (a.path.as_str(), a.line, a.rule).cmp(&(b.path.as_str(), b.line, b.rule))
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_as_file_line_rule_message() {
        let f = Finding {
            path: "solvers/x.rs".to_string(),
            line: 7,
            rule: "budget-convention",
            message: "m".to_string(),
        };
        assert_eq!(f.to_string(), "solvers/x.rs:7: [budget-convention] m");
    }
}
