//! Contract-lint: repo-native static analysis for the standing
//! contracts (ROADMAP "Standing contracts").
//!
//! The determinism and budget guarantees this crate reproduces from the
//! paper — one `solvers::sketch_budget` convention for every sampling
//! budget, bitwise warm/cold and shard-count parity — are enforced
//! dynamically by tier-1 tests, but a test can only catch a call site
//! it already exercises. This pass catches the *new* call site at CI
//! time instead: `repro lint` walks `rust/src`, applies the token-level
//! rules in [`rules::RULES`], and exits nonzero on any finding. Two of
//! the rules encode regressions that were previously found and fixed by
//! hand (nondeterministic `HashMap` flush ids, poisoned-lock
//! double-panics), so the registry is the repo's memory of them.
//!
//! Suppression is two-tier:
//! - `// lint: allow(rule-id, "reason")` on the offending line or the
//!   line directly above suppresses one site; the reason is mandatory
//!   and a pragma that no longer suppresses anything is itself an
//!   error (`lint-pragma`), so justifications cannot rot.
//! - `lint.toml` `[allow]` entries exempt whole files per rule, for
//!   code a pragma cannot reach (e.g. feature-gated modules CI never
//!   compiles).
//!
//! The scanner is line-based with comment/string stripping and
//! brace-level scope tracking — no `syn`, no new dependencies, which is
//! what lets the pass run as `cargo run --release -- lint` in the same
//! image that builds the crate.

pub mod config;
pub mod diagnostics;
pub mod rules;
pub mod scanner;

pub use config::LintConfig;
pub use diagnostics::Finding;
pub use rules::{Rule, RULES};

use std::collections::BTreeSet;
use std::path::Path;

/// Lint one file's source text. `path` must be relative to the lint
/// root with forward slashes (it drives rule scoping and allowlists).
pub fn lint_source(path: &str, content: &str, config: &LintConfig) -> Vec<Finding> {
    let file = scanner::scan(path, content);
    let mut raw: Vec<Finding> = Vec::new();
    for rule in RULES {
        if rule.applies_to(path) && !config.allows(rule.id, path) {
            (rule.check)(&file, &mut raw);
        }
    }

    // Resolve pragmas: a pragma suppresses findings of its rule on its
    // own line or the line directly below (the annotated statement).
    let mut honored: BTreeSet<usize> = BTreeSet::new();
    let mut findings: Vec<Finding> = Vec::new();
    for finding in raw {
        let suppressor = file.pragmas.iter().position(|p| {
            p.rule == finding.rule && (p.line == finding.line || p.line + 1 == finding.line)
        });
        match suppressor {
            Some(i) => {
                honored.insert(i);
            }
            None => findings.push(finding),
        }
    }

    // Pragma hygiene (the `lint-pragma` rule): unknown rule ids,
    // missing reasons, and stale pragmas are findings themselves.
    if !config.allows(rules::PRAGMA_RULE, path) {
        for (i, pragma) in file.pragmas.iter().enumerate() {
            let known = RULES.iter().any(|r| r.id == pragma.rule);
            if !known {
                findings.push(Finding {
                    path: path.to_string(),
                    line: pragma.line,
                    rule: rules::PRAGMA_RULE,
                    message: format!(
                        "pragma names unknown rule '{}' (see `repro lint --list-rules`)",
                        pragma.rule
                    ),
                });
                continue;
            }
            if pragma.reason.is_none() {
                findings.push(Finding {
                    path: path.to_string(),
                    line: pragma.line,
                    rule: rules::PRAGMA_RULE,
                    message: format!(
                        "pragma for '{}' has no reason; write \
                         `// lint: allow({}, \"why this site is safe\")`",
                        pragma.rule, pragma.rule
                    ),
                });
            }
            if !honored.contains(&i) {
                findings.push(Finding {
                    path: path.to_string(),
                    line: pragma.line,
                    rule: rules::PRAGMA_RULE,
                    message: format!(
                        "stale pragma: rule '{}' no longer fires on the next line; \
                         delete the pragma",
                        pragma.rule
                    ),
                });
            }
        }
    }

    diagnostics::sort_findings(&mut findings);
    findings
}

/// Lint every `.rs` file under `src_root` (sorted walk, so output order
/// is stable). The `lint/fixtures/` corpus is skipped — those files are
/// deliberate violations pinned by unit tests.
pub fn lint_tree(src_root: &Path, config: &LintConfig) -> Result<Vec<Finding>, String> {
    let mut files: Vec<std::path::PathBuf> = Vec::new();
    collect_rs_files(src_root, &mut files)?;
    files.sort();
    let mut findings = Vec::new();
    for file in files {
        let rel = file
            .strip_prefix(src_root)
            .map_err(|_| format!("walked outside the root: {}", file.display()))?
            .to_string_lossy()
            .replace('\\', "/");
        if rel.starts_with("lint/fixtures/") {
            continue;
        }
        let content = std::fs::read_to_string(&file)
            .map_err(|e| format!("read {}: {e}", file.display()))?;
        findings.extend(lint_source(&rel, &content, config));
    }
    diagnostics::sort_findings(&mut findings);
    Ok(findings)
}

/// Recursively collect `.rs` files under `dir`.
fn collect_rs_files(dir: &Path, out: &mut Vec<std::path::PathBuf>) -> Result<(), String> {
    let entries = std::fs::read_dir(dir).map_err(|e| format!("read_dir {}: {e}", dir.display()))?;
    for entry in entries {
        let entry = entry.map_err(|e| format!("read_dir {}: {e}", dir.display()))?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|ext| ext == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used)]

    use super::*;

    fn rules_hit(findings: &[Finding]) -> Vec<&'static str> {
        findings.iter().map(|f| f.rule).collect()
    }

    /// Lint a fixture under a virtual scoped path with no allowlists.
    fn lint_fixture(path: &str, src: &str) -> Vec<Finding> {
        lint_source(path, src, &LintConfig::empty())
    }

    #[test]
    fn fixture_budget_bad_fires_and_clean_twin_passes() {
        let bad = include_str!("fixtures/budget_bad.rs");
        assert_eq!(rules_hit(&lint_fixture("solvers/fixture.rs", bad)), vec!["budget-convention"]);
        let clean = lint_fixture("solvers/fixture.rs", include_str!("fixtures/budget_clean.rs"));
        assert!(clean.is_empty(), "{clean:?}");
    }

    #[test]
    fn fixture_budget_bad_is_scope_gated() {
        // The same text outside solvers//engine/ is not budget-checked.
        let bad = include_str!("fixtures/budget_bad.rs");
        let out = lint_fixture("experiments/fixture.rs", bad);
        assert!(out.is_empty(), "{out:?}");
    }

    #[test]
    fn fixture_unordered_bad_fires_and_pragmad_twin_passes() {
        let bad = lint_fixture("coordinator/fixture.rs", include_str!("fixtures/unordered_bad.rs"));
        assert_eq!(rules_hit(&bad), vec!["unordered-iter", "unordered-iter"]);
        // The clean twin holds an honored pragma (reason given, rule
        // still firing underneath) plus a sorted collect — zero
        // findings, including zero stale-pragma findings.
        let src = include_str!("fixtures/unordered_clean.rs");
        let clean = lint_fixture("coordinator/fixture.rs", src);
        assert!(clean.is_empty(), "{clean:?}");
    }

    #[test]
    fn fixture_wallclock_bad_fires_and_clean_twin_passes() {
        let bad = lint_fixture("ot/fixture.rs", include_str!("fixtures/wallclock_bad.rs"));
        assert_eq!(rules_hit(&bad), vec!["wall-clock", "wall-clock"]);
        let clean = lint_fixture("ot/fixture.rs", include_str!("fixtures/wallclock_clean.rs"));
        assert!(clean.is_empty(), "{clean:?}");
    }

    #[test]
    fn fixture_wallclock_is_legal_in_net_but_not_engine() {
        // The wall-clock rule stops at the serving boundary: the same
        // timeout/poll code is clean under net/ (operational, cannot
        // affect results) and fires line-for-line under engine/.
        let src = include_str!("fixtures/wallclock_net_ok.rs");
        let in_net = lint_fixture("net/fixture.rs", src);
        assert!(in_net.is_empty(), "{in_net:?}");
        let in_engine = lint_fixture("engine/fixture.rs", src);
        assert_eq!(rules_hit(&in_engine), vec!["wall-clock", "wall-clock"]);
    }

    #[test]
    fn fixture_wallclock_is_legal_in_bench_but_not_sparse_or_ot() {
        // The bench harness owns timing: the kernels arm's measurement
        // code is clean under bench/, while the same clock reads fire
        // line-for-line inside the kernels it measures (sparse/, ot/).
        let src = include_str!("fixtures/wallclock_bench_ok.rs");
        let in_bench = lint_fixture("bench/kernels.rs", src);
        assert!(in_bench.is_empty(), "{in_bench:?}");
        let in_sparse = lint_fixture("sparse/fixture.rs", src);
        assert_eq!(rules_hit(&in_sparse), vec!["wall-clock", "wall-clock"]);
        let in_ot = lint_fixture("ot/fixture.rs", src);
        assert_eq!(rules_hit(&in_ot), vec!["wall-clock", "wall-clock"]);
    }

    #[test]
    fn fixture_lock_bad_fires_and_helper_twin_passes() {
        let bad = lint_fixture("pool/fixture.rs", include_str!("fixtures/lock_bad.rs"));
        assert_eq!(rules_hit(&bad), vec!["lock-unwrap", "lock-unwrap"]);
        let clean = lint_fixture("pool/fixture.rs", include_str!("fixtures/lock_clean.rs"));
        assert!(clean.is_empty(), "{clean:?}");
    }

    #[test]
    fn fixture_stale_and_unknown_pragmas_are_flagged() {
        let out = lint_fixture("metrics_fixture.rs", include_str!("fixtures/pragma_stale.rs"));
        assert_eq!(rules_hit(&out), vec!["lint-pragma", "lint-pragma"]);
        assert!(out[0].message.contains("stale"), "{}", out[0]);
        assert!(out[1].message.contains("unknown rule"), "{}", out[1]);
    }

    #[test]
    fn fixture_missing_reason_still_suppresses_but_errors() {
        let src = include_str!("fixtures/pragma_missing_reason.rs");
        let out = lint_fixture("coordinator/fixture.rs", src);
        // The underlying unordered-iter finding is suppressed, but the
        // reasonless pragma is itself an error.
        assert_eq!(rules_hit(&out), vec!["lint-pragma"]);
        assert!(out[0].message.contains("no reason"), "{}", out[0]);
    }

    #[test]
    fn allowlist_silences_a_rule_for_a_file() {
        let cfg = LintConfig::parse("[allow]\nlock-unwrap = [\"pool/fixture.rs\"]\n").unwrap();
        let out = lint_source("pool/fixture.rs", include_str!("fixtures/lock_bad.rs"), &cfg);
        assert!(out.is_empty(), "{out:?}");
    }

    #[test]
    fn findings_come_out_sorted() {
        let out = lint_fixture("pool/fixture.rs", include_str!("fixtures/lock_bad.rs"));
        let lines: Vec<usize> = out.iter().map(|f| f.line).collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0] < lines[1], "{lines:?}");
    }
}
