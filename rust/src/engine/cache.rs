//! Content-addressed artifact cache: [`Fingerprint`] →
//! [`CostArtifacts`] with a byte-budget LRU and hit/miss/eviction
//! counters.
//!
//! Consumers call [`ArtifactCache::get_or_build`]: the first caller for
//! a fingerprint builds (under the lock, so artifacts are constructed
//! exactly once per fingerprint even with many workers racing); every
//! later caller gets the resident `Arc`. Eviction keeps resident bytes
//! at or below the budget at all times — an artifact larger than the
//! whole budget is handed to its caller but never retained.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use super::artifacts::{CostArtifacts, CostHandle, Fingerprint};

/// Default byte budget for [`global_cache`] (overridable via the
/// `SPAR_SINK_CACHE_BYTES` env var): 512 MiB.
pub const DEFAULT_CACHE_BYTES: usize = 512 << 20;

/// Point-in-time cache counters/gauges, surfaced through
/// [`MetricsSnapshot`](crate::coordinator::MetricsSnapshot).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Lookups served from a resident artifact.
    pub hits: u64,
    /// Lookups that had to build.
    pub misses: u64,
    /// Artifacts dropped to respect the byte budget (including
    /// oversized artifacts never retained).
    pub evictions: u64,
    /// Resident artifact count.
    pub entries: usize,
    /// Resident bytes (always ≤ `byte_budget`).
    pub bytes: usize,
    /// Configured byte budget.
    pub byte_budget: usize,
}

impl CacheStats {
    /// One-line rendering for service metrics output.
    pub fn render(&self) -> String {
        format!(
            "{} hits / {} misses / {} evictions, {} entries ({} B / {} B budget)",
            self.hits, self.misses, self.evictions, self.entries, self.bytes, self.byte_budget
        )
    }
}

struct Slot {
    artifacts: Arc<CostArtifacts>,
    bytes: usize,
    last_used: u64,
}

struct Inner {
    entries: HashMap<Fingerprint, Slot>,
    bytes: usize,
    tick: u64,
}

/// The content-addressed, byte-budgeted LRU artifact cache.
pub struct ArtifactCache {
    byte_budget: usize,
    inner: Mutex<Inner>,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

impl ArtifactCache {
    pub fn new(byte_budget: usize) -> Self {
        ArtifactCache {
            byte_budget,
            inner: Mutex::new(Inner { entries: HashMap::new(), bytes: 0, tick: 0 }),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    /// Budget from `SPAR_SINK_CACHE_BYTES`, else [`DEFAULT_CACHE_BYTES`].
    pub fn with_default_budget() -> Self {
        let budget = std::env::var("SPAR_SINK_CACHE_BYTES")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(DEFAULT_CACHE_BYTES);
        Self::new(budget)
    }

    /// Look up a resident artifact (refreshes its LRU position; counts
    /// as neither hit nor miss — use [`ArtifactCache::get_or_build`] on
    /// solve paths).
    pub fn peek(&self, fingerprint: &Fingerprint) -> Option<CostHandle> {
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        inner.entries.get_mut(fingerprint).map(|slot| {
            slot.last_used = tick;
            CostHandle::new(slot.artifacts.clone())
        })
    }

    /// Return the resident artifact for `fingerprint`, building it via
    /// `build` on a miss. The build runs under the cache lock, so
    /// concurrent workers construct each artifact exactly once — the
    /// deliberate tradeoff being that a long O(n·m) build briefly
    /// stalls hits on OTHER fingerprints too. That is still strictly
    /// better than the cold path (where every worker paid the build),
    /// and per-fingerprint single-flight is the noted follow-up for
    /// many-ε workloads (see ROADMAP).
    pub fn get_or_build(
        &self,
        fingerprint: Fingerprint,
        build: impl FnOnce() -> Arc<CostArtifacts>,
    ) -> CostHandle {
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        if let Some(slot) = inner.entries.get_mut(&fingerprint) {
            slot.last_used = tick;
            self.hits.fetch_add(1, Ordering::Relaxed);
            return CostHandle::new(slot.artifacts.clone());
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let artifacts = build();
        debug_assert_eq!(artifacts.fingerprint(), fingerprint, "artifact/fingerprint mismatch");
        let bytes = artifacts.bytes();
        let handle = CostHandle::new(artifacts.clone());
        if bytes > self.byte_budget {
            // Oversized: the caller still gets it, but it is never
            // resident (the budget invariant holds at all times).
            self.evictions.fetch_add(1, Ordering::Relaxed);
            return handle;
        }
        inner.entries.insert(fingerprint, Slot { artifacts, bytes, last_used: tick });
        inner.bytes += bytes;
        while inner.bytes > self.byte_budget {
            // Evict strictly least-recently-used; the just-inserted slot
            // carries the newest tick, so it is evicted last — and the
            // loop terminates because its bytes alone fit the budget.
            let victim = inner
                .entries
                .iter()
                .filter(|(fp, _)| **fp != fingerprint)
                .min_by_key(|(_, slot)| slot.last_used)
                .map(|(fp, _)| *fp);
            let Some(fp) = victim else { break };
            if let Some(slot) = inner.entries.remove(&fp) {
                inner.bytes -= slot.bytes;
                self.evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
        handle
    }

    /// Current counters and gauges.
    pub fn stats(&self) -> CacheStats {
        let inner = self.inner.lock().unwrap();
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            entries: inner.entries.len(),
            bytes: inner.bytes,
            byte_budget: self.byte_budget,
        }
    }

    /// Drop every resident artifact (counters are preserved).
    pub fn clear(&self) {
        let mut inner = self.inner.lock().unwrap();
        inner.entries.clear();
        inner.bytes = 0;
    }
}

/// The process-wide cache behind [`crate::api::solve_batch`] and the
/// CLI. Services that need isolated counters (the coordinator, tests)
/// hold their own [`ArtifactCache`].
pub fn global_cache() -> &'static ArtifactCache {
    static GLOBAL: OnceLock<ArtifactCache> = OnceLock::new();
    GLOBAL.get_or_init(ArtifactCache::with_default_budget)
}

#[cfg(test)]
mod tests {
    use super::super::artifacts::FormulationKey;
    use super::*;

    fn pts(n: usize, seed: u64) -> Vec<Vec<f64>> {
        let mut rng = crate::rng::Rng::seed_from(seed);
        (0..n).map(|_| vec![rng.uniform(), rng.uniform()]).collect()
    }

    fn build_for(seed: u64, eps: f64) -> (Fingerprint, Arc<CostArtifacts>) {
        let p = pts(16, seed);
        let key = FormulationKey::Balanced;
        let arts = CostArtifacts::for_sq_euclidean_support(&p, eps, key);
        (arts.fingerprint(), arts)
    }

    #[test]
    fn hit_returns_the_same_artifacts() {
        let cache = ArtifactCache::new(64 << 20);
        let (fp, arts) = build_for(1, 0.1);
        let first = cache.get_or_build(fp, || arts.clone());
        let second = cache.get_or_build(fp, || panic!("must not rebuild on a hit"));
        assert!(Arc::ptr_eq(&first.share(), &second.share()));
        let stats = cache.stats();
        assert_eq!((stats.hits, stats.misses, stats.evictions), (1, 1, 0));
        assert_eq!(stats.entries, 1);
        assert!(stats.bytes > 0 && stats.bytes <= stats.byte_budget);
    }

    #[test]
    fn lru_eviction_respects_byte_budget() {
        let (_, probe) = build_for(1, 0.1);
        let one = probe.bytes();
        // Room for two artifacts, not three.
        let cache = ArtifactCache::new(2 * one + one / 2);
        for seed in 1..=5u64 {
            let (fp, arts) = build_for(seed, 0.1);
            cache.get_or_build(fp, || arts);
            let stats = cache.stats();
            assert!(stats.bytes <= stats.byte_budget, "{stats:?}");
            assert!(stats.entries <= 2, "{stats:?}");
        }
        let stats = cache.stats();
        assert_eq!(stats.misses, 5);
        assert_eq!(stats.evictions, 3);
        // The most recent fingerprint must still be resident.
        let (fp5, _) = build_for(5, 0.1);
        assert!(cache.peek(&fp5).is_some());
        let (fp1, _) = build_for(1, 0.1);
        assert!(cache.peek(&fp1).is_none());
    }

    #[test]
    fn oversized_artifact_is_served_but_not_retained() {
        let (fp, arts) = build_for(7, 0.1);
        let cache = ArtifactCache::new(arts.bytes() - 1);
        let handle = cache.get_or_build(fp, || arts.clone());
        assert!(Arc::ptr_eq(&handle.share(), &arts));
        let stats = cache.stats();
        assert_eq!(stats.entries, 0);
        assert_eq!(stats.bytes, 0);
        assert_eq!(stats.evictions, 1);
    }

    #[test]
    fn clear_preserves_counters() {
        let cache = ArtifactCache::new(64 << 20);
        let (fp, arts) = build_for(9, 0.1);
        cache.get_or_build(fp, || arts.clone());
        cache.clear();
        let stats = cache.stats();
        assert_eq!(stats.entries, 0);
        assert_eq!(stats.bytes, 0);
        assert_eq!(stats.misses, 1);
        // Next lookup rebuilds.
        cache.get_or_build(fp, || arts);
        assert_eq!(cache.stats().misses, 2);
    }
}
