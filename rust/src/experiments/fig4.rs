//! Figure 4 — RMAE(OT) versus n under C1 at fixed budget s = 8·s₀(n),
//! adding the non-subsampling baselines Greenkhorn and Screenkhorn.
//! Screenkhorn is omitted at ε = 1e-3 (it fails there; the paper does
//! the same).

use super::common::{exact_ot, ot_cost, rmae_over_reps, run_method_ot, Method};
use super::{ExperimentOutput, Profile};
use crate::api::{self, OtProblem, SolverSpec};
use crate::data::synthetic::{instance, Scenario};
use crate::rng::Rng;
use crate::util::json::Json;
use crate::util::table::{f, Table};

/// Figure 4: RMAE(OT) vs n under C1, including the Greenkhorn and Screenkhorn baselines.
pub fn run(profile: Profile) -> ExperimentOutput {
    // Paper: n in {4,8,...,128} x 100; quick: {2,4,8} x 100.
    let ns: Vec<usize> = profile.pick(vec![200, 400, 800], vec![400, 800, 1600, 3200, 6400, 12800]);
    let reps = profile.reps(5, 100);
    let epss = [1e-1, 1e-2, 1e-3];
    let d = 5;
    let s_mult = 8.0;

    let mut table = Table::new(&["eps", "n", "method", "rmae", "se", "fail"]);
    let mut rows = Vec::new();
    let mut rng = Rng::seed_from(0xF164);
    for &eps in &epss {
        for &n in &ns {
            let inst = instance(Scenario::C1, n, d, 1.0, 1.0, &mut rng);
            let cost = ot_cost(&inst.points);
            let Ok(truth) = exact_ot(&cost, &inst.a, &inst.b, eps) else {
                continue;
            };
            // Subsampling methods.
            for method in Method::all() {
                let (rmae, se, failures) = rmae_over_reps(
                    reps,
                    truth,
                    |r| run_method_ot(method, &cost, &inst.a, &inst.b, eps, s_mult, r),
                    &mut rng,
                );
                push(&mut table, &mut rows, eps, n, method.name(), rmae, se, failures);
            }
            // The non-subsampling baselines, through the same registry
            // surface (deterministic given the instance). Screenkhorn is
            // omitted for eps = 1e-3 (paper Sec. 5.1).
            let problem = OtProblem::balanced(&cost, inst.a.clone(), inst.b.clone(), eps);
            let mut baselines = vec![api::Method::Greenkhorn];
            if eps > 1e-3 {
                baselines.push(api::Method::Screenkhorn);
            }
            for baseline in baselines {
                match api::solve(&problem, &SolverSpec::new(baseline)) {
                    Ok(sol) => {
                        let rmae = (sol.objective - truth).abs() / truth.abs();
                        push(&mut table, &mut rows, eps, n, baseline.name(), rmae, 0.0, 0);
                    }
                    Err(_) => {
                        push(&mut table, &mut rows, eps, n, baseline.name(), f64::NAN, 0.0, 1)
                    }
                }
            }
        }
    }
    let text = format!(
        "Figure 4 — RMAE(OT) vs n under C1 (d = {d}, s = 8 s0(n), {reps} reps for sampling methods)\n{}",
        table.render()
    );
    ExperimentOutput { id: "fig4", text, rows: Json::arr(rows) }
}

#[allow(clippy::too_many_arguments)]
fn push(
    table: &mut Table,
    rows: &mut Vec<Json>,
    eps: f64,
    n: usize,
    method: &str,
    rmae: f64,
    se: f64,
    failures: usize,
) {
    table.row(vec![
        format!("{eps:.0e}"),
        n.to_string(),
        method.into(),
        f(rmae, 4),
        f(se, 4),
        failures.to_string(),
    ]);
    rows.push(super::common::row(vec![
        ("eps", Json::num(eps)),
        ("n", Json::num(n as f64)),
        ("method", Json::str(method)),
        ("rmae", Json::num(rmae)),
        ("se", Json::num(se)),
    ]));
}
