//! Accelerated Sinkhorn variants: the paper's Spar-Sink / Spar-IBP and
//! every baseline in the evaluation section.
//!
//! | Solver | Paper | Per-iteration cost |
//! |---|---|---|
//! | [`spar_sink`] | Alg. 3-4 (this paper) | O(s), s = Õ(n) |
//! | [`rand_sink`] | uniform-sampling ablation | O(s) |
//! | [`nys_sink`] | Altschuler et al. 2019 (+ robust variant, Le et al. 2021) | O(nr) |
//! | [`greenkhorn`] | Altschuler et al. 2017 | O(n) per greedy update |
//! | [`screenkhorn`] | Alaya et al. 2019 | O((n/κ)²) |
//! | [`spar_ibp`] | Alg. 6 (this paper) | O(ms) |
//!
//! The multiplicative sparse loop ([`sparse_loop`]) and its log-domain
//! stabilized twin ([`log_sparse`]) sit behind the
//! [`backend::ScalingBackend`] switch, which auto-escalates to the log
//! engine for small ε or on numerical failure.

pub mod backend;
pub mod greenkhorn;
pub mod log_sparse;
pub mod nys_sink;
pub mod proximal;
pub mod rand_sink;
pub mod screenkhorn;
pub mod spar_ibp;
pub mod spar_sink;
pub mod sparse_loop;
