//! Algorithm 5 — iterative Bregman projection (IBP) for fixed-support
//! Wasserstein barycenters (Benamou et al., 2015).
//!
//! Solves `min_q Σ_k w_k OT_ε(q, b_k)` by alternating KL projections;
//! the barycenter is read off the shared row marginal.

use crate::error::{Error, Result};
use crate::linalg::{l1_diff, Mat};
use crate::ot::sinkhorn::{safe_div, SinkhornParams};

/// Result of an IBP solve.
#[derive(Clone, Debug)]
pub struct BarycenterSolution {
    /// The barycenter histogram `q`.
    pub q: Vec<f64>,
    /// Iterations performed.
    pub iterations: usize,
    /// Final L1 change in `q`.
    pub displacement: f64,
    /// Whether the tolerance was met.
    pub converged: bool,
}

/// A kernel operator abstraction so IBP runs over dense matrices and
/// sparse sketches alike (the Spar-IBP solver reuses this loop).
pub trait KernelOp: Sync {
    /// `y = K x`.
    fn apply(&self, x: &[f64]) -> Vec<f64>;
    /// `y = Kᵀ x`.
    fn apply_t(&self, x: &[f64]) -> Vec<f64>;
    /// Number of kernel rows.
    fn rows(&self) -> usize;
    /// Number of kernel columns.
    fn cols(&self) -> usize;
}

impl<K: KernelOp> KernelOp for &K {
    fn apply(&self, x: &[f64]) -> Vec<f64> {
        (**self).apply(x)
    }
    fn apply_t(&self, x: &[f64]) -> Vec<f64> {
        (**self).apply_t(x)
    }
    fn rows(&self) -> usize {
        (**self).rows()
    }
    fn cols(&self) -> usize {
        (**self).cols()
    }
}

impl KernelOp for Mat {
    fn apply(&self, x: &[f64]) -> Vec<f64> {
        self.matvec(x)
    }
    fn apply_t(&self, x: &[f64]) -> Vec<f64> {
        self.matvec_t(x)
    }
    fn rows(&self) -> usize {
        Mat::rows(self)
    }
    fn cols(&self) -> usize {
        Mat::cols(self)
    }
}

/// Run IBP over any kernel operators (Algorithm 5).
///
/// * `kernels[k]` — Gibbs kernel for the k-th input measure.
/// * `bs[k]` — the k-th input histogram.
/// * `weights` — simplex weights `w`.
pub fn ibp_barycenter_with<K: KernelOp>(
    kernels: &[K],
    bs: &[Vec<f64>],
    weights: &[f64],
    params: &SinkhornParams,
) -> Result<BarycenterSolution> {
    let m = kernels.len();
    if m == 0 || bs.len() != m || weights.len() != m {
        return Err(Error::Dimension(format!(
            "got {} kernels, {} measures, {} weights",
            m,
            bs.len(),
            weights.len()
        )));
    }
    let n = kernels[0].rows();
    for (k, kern) in kernels.iter().enumerate() {
        if kern.rows() != n || kern.cols() != bs[k].len() {
            return Err(Error::Dimension(format!(
                "kernel {k} is {}x{} but barycenter support is {n} and b[{k}] has {}",
                kern.rows(),
                kern.cols(),
                bs[k].len()
            )));
        }
    }
    let wsum: f64 = weights.iter().sum();
    if weights.iter().any(|&w| w < 0.0) || wsum <= 0.0 {
        return Err(Error::InvalidParam("weights must be non-negative with positive sum".into()));
    }
    let w: Vec<f64> = weights.iter().map(|x| x / wsum).collect();

    let mut q = vec![1.0 / n as f64; n];
    let mut q_prev = q.clone();
    let mut us: Vec<Vec<f64>> = (0..m).map(|_| vec![1.0; n]).collect();
    let mut displacement = f64::INFINITY;
    let mut iters = 0;
    while iters < params.max_iters {
        iters += 1;
        q_prev.copy_from_slice(&q);
        // Geometric-mean update: q = prod_k (K_k v_k)^{w_k}.
        let mut log_q = vec![0.0; n];
        for k in 0..m {
            // v_k = b_k ./ K_k^T u_k
            let ktu = kernels[k].apply_t(&us[k]);
            let v_k: Vec<f64> =
                bs[k].iter().zip(&ktu).map(|(&b, &d)| safe_div(b, d)).collect();
            let kv = kernels[k].apply(&v_k);
            for i in 0..n {
                // Guard log(0): treat empty rows as tiny mass.
                log_q[i] += w[k] * kv[i].max(1e-300).ln();
            }
            us[k] = kv; // stash K_k v_k; u_k update below uses new q.
        }
        for i in 0..n {
            q[i] = log_q[i].exp();
        }
        // u_k = q ./ (K_k v_k)
        for u_k in us.iter_mut() {
            for i in 0..n {
                u_k[i] = safe_div(q[i], u_k[i]);
            }
        }
        if q.iter().any(|x| !x.is_finite()) {
            return Err(Error::Numerical(format!("barycenter diverged at iteration {iters}")));
        }
        displacement = l1_diff(&q, &q_prev);
        if displacement <= params.delta {
            return Ok(BarycenterSolution { q, iterations: iters, displacement, converged: true });
        }
    }
    if params.strict {
        return Err(Error::NotConverged { iters, err: displacement });
    }
    Ok(BarycenterSolution { q, iterations: iters, displacement, converged: false })
}

/// Dense-matrix convenience wrapper.
pub fn ibp_barycenter(
    kernels: &[Mat],
    bs: &[Vec<f64>],
    weights: &[f64],
    params: &SinkhornParams,
) -> Result<BarycenterSolution> {
    ibp_barycenter_with(kernels, bs, weights, params)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ot::cost::{gibbs_kernel, sq_euclidean_cost};

    fn grid_support(n: usize) -> Vec<Vec<f64>> {
        (0..n).map(|i| vec![i as f64 / (n - 1) as f64]).collect()
    }

    fn gauss_hist(pts: &[Vec<f64>], mu: f64, s2: f64) -> Vec<f64> {
        let w: Vec<f64> = pts.iter().map(|p| (-(p[0] - mu).powi(2) / (2.0 * s2)).exp()).collect();
        let s: f64 = w.iter().sum();
        w.iter().map(|x| x / s).collect()
    }

    #[test]
    fn barycenter_of_identical_measures_recovers_shape() {
        // Entropic IBP returns a slightly blurred version of b; the mean,
        // total mass and mode must match even if pointwise values differ.
        let pts = grid_support(32);
        let cost = sq_euclidean_cost(&pts, &pts);
        let kernel = gibbs_kernel(&cost, 0.002);
        let b = gauss_hist(&pts, 0.5, 0.01);
        let sol = ibp_barycenter(
            &[kernel.clone(), kernel.clone()],
            &[b.clone(), b.clone()],
            &[0.5, 0.5],
            &SinkhornParams { delta: 1e-10, max_iters: 3000, strict: false },
        )
        .unwrap();
        let mass: f64 = sol.q.iter().sum();
        assert!((mass - 1.0).abs() < 1e-6, "mass {mass}");
        let mean: f64 = pts.iter().zip(&sol.q).map(|(p, q)| p[0] * q).sum();
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
        let mode = sol.q.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0;
        let mode_b = b.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0;
        assert!((mode as i64 - mode_b as i64).abs() <= 1, "mode {mode} vs {mode_b}");
        let err: f64 = l1_diff(&sol.q, &b);
        assert!(err < 0.25, "L1 error {err} (entropic blur should be modest)");
    }

    #[test]
    fn barycenter_interpolates_between_two_gaussians() {
        let pts = grid_support(48);
        let cost = sq_euclidean_cost(&pts, &pts);
        let kernel = gibbs_kernel(&cost, 0.005);
        let b1 = gauss_hist(&pts, 0.25, 0.004);
        let b2 = gauss_hist(&pts, 0.75, 0.004);
        let sol = ibp_barycenter(
            &[kernel.clone(), kernel.clone()],
            &[b1, b2],
            &[0.5, 0.5],
            &SinkhornParams { delta: 1e-9, max_iters: 5000, strict: false },
        )
        .unwrap();
        // The W2 barycenter of N(0.25, s) and N(0.75, s) has mean 0.5.
        let mean: f64 = pts.iter().zip(&sol.q).map(|(p, q)| p[0] * q).sum();
        assert!((mean - 0.5).abs() < 0.02, "barycenter mean {mean}");
        let mass: f64 = sol.q.iter().sum();
        assert!((mass - 1.0).abs() < 1e-6, "mass {mass}");
    }

    #[test]
    fn weights_skew_the_barycenter() {
        let pts = grid_support(48);
        let cost = sq_euclidean_cost(&pts, &pts);
        let kernel = gibbs_kernel(&cost, 0.005);
        let b1 = gauss_hist(&pts, 0.25, 0.004);
        let b2 = gauss_hist(&pts, 0.75, 0.004);
        let sol = ibp_barycenter(
            &[kernel.clone(), kernel.clone()],
            &[b1, b2],
            &[0.9, 0.1],
            &SinkhornParams { delta: 1e-9, max_iters: 5000, strict: false },
        )
        .unwrap();
        let mean: f64 = pts.iter().zip(&sol.q).map(|(p, q)| p[0] * q).sum();
        assert!(mean < 0.4, "mean {mean} should be pulled toward 0.25");
    }

    #[test]
    fn rejects_mismatched_inputs() {
        let pts = grid_support(8);
        let cost = sq_euclidean_cost(&pts, &pts);
        let kernel = gibbs_kernel(&cost, 0.1);
        let b = gauss_hist(&pts, 0.5, 0.01);
        let res = ibp_barycenter(&[kernel], &[b.clone(), b], &[0.5, 0.5], &SinkhornParams::default());
        assert!(res.is_err());
    }

    #[test]
    fn rejects_bad_weights() {
        let pts = grid_support(8);
        let cost = sq_euclidean_cost(&pts, &pts);
        let kernel = gibbs_kernel(&cost, 0.1);
        let b = gauss_hist(&pts, 0.5, 0.01);
        let res = ibp_barycenter(
            &[kernel.clone(), kernel],
            &[b.clone(), b],
            &[-1.0, 0.5],
            &SinkhornParams::default(),
        );
        assert!(res.is_err());
    }
}
