//! Job and result types for the distance service.
//!
//! A job's supports double as its placement key: the scheduler routes
//! batches by the cost [`Fingerprint`](crate::engine::Fingerprint) of
//! their jobs (support pair + η, ε, formulation), so jobs sharing a
//! `Measure`'s `Arc`-shared points — a video's frames, a barycenter
//! support — land on one shard and hit that shard's warm artifacts.
//! Placement never affects results, only where they are computed.

use std::sync::Arc;

use crate::engine::{Fingerprint, FormulationKey, SHARED_ARTIFACT_ENTRY_CAP};
use crate::solvers::backend::{BackendKind, ScalingBackend};

/// Which solver executes a job: the coordinator dispatches every method
/// registered in [`crate::api`], so this is the unified [`Method`]
/// re-exported. UOT-only jobs submitted to an OT-only solver (e.g.
/// `greenkhorn`) come back with the registry's error in
/// [`DistanceResult::error`] rather than failing the service.
pub use crate::api::Method;

/// A discrete measure: support points + masses (shared across jobs via
/// `Arc` so a video's frames are stored once).
#[derive(Clone, Debug)]
pub struct Measure {
    /// Support points (one coordinate vector per atom).
    pub points: Arc<Vec<Vec<f64>>>,
    /// Mass at each support point (not necessarily normalized — UOT).
    pub mass: Arc<Vec<f64>>,
}

impl Measure {
    /// Wrap a support and its masses (must have equal lengths).
    pub fn new(points: Vec<Vec<f64>>, mass: Vec<f64>) -> Self {
        assert_eq!(points.len(), mass.len(), "support/mass length mismatch");
        Measure { points: Arc::new(points), mass: Arc::new(mass) }
    }

    /// Number of support points.
    pub fn len(&self) -> usize {
        self.mass.len()
    }

    /// Whether the measure has no support points.
    pub fn is_empty(&self) -> bool {
        self.mass.is_empty()
    }
}

/// Problem parameters shared by a family of jobs.
#[derive(Clone, Debug)]
pub struct ProblemSpec {
    /// Marginal relaxation λ (WFR distance).
    pub lambda: f64,
    /// Entropic regularization ε.
    pub eps: f64,
    /// WFR truncation radius η.
    pub eta: f64,
    /// Subsample budget in units of s₀(n) (ignored by `Sinkhorn`).
    pub s_multiplier: f64,
    /// Sinkhorn stopping threshold δ.
    pub delta: f64,
    /// Iteration cap.
    pub max_iters: usize,
    /// Per-job scaling-backend override: `None` = the solver's default
    /// policy (`Auto` for the sparse family — multiplicative above the
    /// ε threshold, log-domain below it or on numerical failure).
    pub backend: Option<ScalingBackend>,
}

impl Default for ProblemSpec {
    fn default() -> Self {
        // Section 6 defaults: eps = 0.01 (scaled), lambda = 1, eta = 15.
        ProblemSpec {
            lambda: 1.0,
            eps: 0.01,
            eta: 15.0,
            s_multiplier: 8.0,
            delta: 1e-6,
            max_iters: 1000,
            backend: None,
        }
    }
}

/// A single WFR-distance job between two measures.
#[derive(Clone, Debug)]
pub struct DistanceJob {
    /// Client-assigned id, echoed in the result.
    pub id: u64,
    /// Source measure (cost rows).
    pub source: Measure,
    /// Target measure (cost columns).
    pub target: Measure,
    /// Which registered solver runs the job.
    pub method: Method,
    /// Problem parameters (ε, λ, η, budget, stopping rule, backend).
    pub spec: ProblemSpec,
    /// RNG seed for the sparsifier (deterministic per job).
    pub seed: u64,
}

impl DistanceJob {
    /// The content address of this job's cost geometry, when it fits
    /// [`SHARED_ARTIFACT_ENTRY_CAP`] — the SAME fingerprint the worker
    /// resolves through the artifact cache, computed once and shared by
    /// the shard router, the multi-process balancer
    /// ([`crate::net`]), and the solve path, so routing and caching can
    /// never disagree. `None` = oversized or empty: the worker keeps
    /// the cold oracle path and routers fall back to round-robin.
    pub fn routing_fingerprint(&self) -> Option<Fingerprint> {
        let cells = self.source.len() * self.target.len();
        (cells > 0 && cells <= SHARED_ARTIFACT_ENTRY_CAP).then(|| {
            Fingerprint::for_supports(
                &self.source.points,
                &self.target.points,
                Some(self.spec.eta),
                self.spec.eps,
                FormulationKey::unbalanced(self.spec.lambda),
            )
        })
    }
}

/// A fixed-support Wasserstein-barycenter job: input histograms living
/// on one shared support, combined with simplex weights. Dispatched to
/// the barycenter-capable methods (`sinkhorn` = exact IBP, `spar-ibp` =
/// Algorithm 6); per-job [`ProblemSpec::backend`] overrides are honored
/// exactly as for distance jobs, and `Auto` escalations feed the same
/// per-method counters in
/// [`MetricsSnapshot`](super::MetricsSnapshot).
#[derive(Clone, Debug)]
pub struct BarycenterJob {
    /// Client-assigned id, echoed in the result.
    pub id: u64,
    /// Shared support points (squared-Euclidean ground cost).
    pub support: Arc<Vec<Vec<f64>>>,
    /// Input histograms, each of the support's length.
    pub marginals: Vec<Vec<f64>>,
    /// Barycentric weights (normalized by the solver).
    pub weights: Vec<f64>,
    /// Which registered solver runs the job.
    pub method: Method,
    /// Problem parameters (ε, budget, stopping rule, backend).
    pub spec: ProblemSpec,
    /// RNG seed for the sparsifier (deterministic per job).
    pub seed: u64,
}

impl BarycenterJob {
    /// Support size (the problem dimension n).
    pub fn support_len(&self) -> usize {
        self.support.len()
    }

    /// Barycenter analogue of
    /// [`DistanceJob::routing_fingerprint`]: the shared support against
    /// itself under the barycenter formulation, when `n²` fits the
    /// shared-artifact cap.
    pub fn routing_fingerprint(&self) -> Option<Fingerprint> {
        let n = self.support_len();
        (n > 0 && n * n <= SHARED_ARTIFACT_ENTRY_CAP).then(|| {
            Fingerprint::for_supports(
                &self.support,
                &self.support,
                None,
                self.spec.eps,
                FormulationKey::Barycenter,
            )
        })
    }
}

/// Result of a barycenter job.
#[derive(Clone, Debug)]
pub struct BarycenterResult {
    /// The id the job was submitted with.
    pub id: u64,
    /// The barycenter histogram `q` (empty on error).
    pub q: Vec<f64>,
    /// IBP iterations used.
    pub iterations: usize,
    /// Whether the stopping rule was met.
    pub converged: bool,
    /// Which scaling engine actually produced the solution (`None` on
    /// error).
    pub backend: Option<BackendKind>,
    /// End-to-end latency (queue + solve).
    pub latency: std::time::Duration,
    /// Which batch the job ran in (diagnostics).
    pub batch_id: u64,
    /// Error message if the solve failed.
    pub error: Option<String>,
}

/// Result of a distance job.
#[derive(Clone, Debug)]
pub struct DistanceResult {
    /// The id the job was submitted with.
    pub id: u64,
    /// WFR distance (sqrt of the UOT objective, clamped at 0).
    pub distance: f64,
    /// Raw entropic UOT objective.
    pub objective: f64,
    /// Solver iterations used.
    pub iterations: usize,
    /// Which scaling engine actually produced the solution (`None` on
    /// error, or for solvers outside the backend switch).
    pub backend: Option<BackendKind>,
    /// End-to-end latency (queue + solve).
    pub latency: std::time::Duration,
    /// Which batch the job ran in (diagnostics).
    pub batch_id: u64,
    /// Error message if the solve failed.
    pub error: Option<String>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_shares_storage() {
        let m = Measure::new(vec![vec![0.0, 1.0]], vec![1.0]);
        let m2 = m.clone();
        assert!(Arc::ptr_eq(&m.points, &m2.points));
        assert_eq!(m.len(), 1);
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn measure_rejects_mismatch() {
        Measure::new(vec![vec![0.0]], vec![1.0, 2.0]);
    }

    #[test]
    fn default_spec_matches_paper_section6() {
        let spec = ProblemSpec::default();
        assert_eq!(spec.lambda, 1.0);
        assert_eq!(spec.eps, 0.01);
        assert_eq!(spec.eta, 15.0);
        assert_eq!(spec.s_multiplier, 8.0);
        assert!(spec.backend.is_none());
    }

    #[test]
    fn coordinator_method_is_the_api_method() {
        // One dispatch vocabulary end to end: the coordinator accepts
        // exactly the registry's methods.
        for m in Method::ALL {
            assert!(crate::api::lookup(m.name()).is_some(), "{m:?}");
        }
    }
}
