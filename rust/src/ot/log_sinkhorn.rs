//! Log-domain stabilized Sinkhorn — the standard remedy for the
//! numerical-instability regime (small ε) that the paper addresses by
//! citing Xie et al. (2020). Iterates on the dual potentials
//! `(α, β)` directly:
//!
//! ```text
//! α_i ← ε log a_i − ε log Σ_j exp((−C_ij + β_j)/ε) + α_i·0   (balanced)
//! ```
//!
//! using streaming log-sum-exp, so no kernel entry ever underflows.
//! Used as the reference truth for ε below the f64 underflow point of
//! the multiplicative updates, and exposed publicly as part of the
//! library API.

use super::objective::{kl_divergence, plan_entropy};
use super::SinkhornSolution;
use crate::error::{Error, Result};
use crate::linalg::Mat;
use crate::ot::sinkhorn::SinkhornParams;
use crate::pool;

/// Streaming log-sum-exp of `(-C_ij + β_j) / ε` over j for row i.
#[inline]
fn row_lse(cost_row: &[f64], beta: &[f64], eps: f64) -> f64 {
    let mut max = f64::NEG_INFINITY;
    for (c, b) in cost_row.iter().zip(beta) {
        if c.is_finite() {
            max = max.max((-c + b) / eps);
        }
    }
    if max == f64::NEG_INFINITY {
        return f64::NEG_INFINITY;
    }
    let mut acc = 0.0;
    for (c, b) in cost_row.iter().zip(beta) {
        if c.is_finite() {
            acc += ((-c + b) / eps - max).exp();
        }
    }
    max + acc.ln()
}

/// Log-domain Sinkhorn for balanced entropic OT: works directly with
/// the cost matrix (no Gibbs kernel), stable for arbitrarily small ε.
pub fn log_sinkhorn_ot(
    cost: &Mat,
    a: &[f64],
    b: &[f64],
    eps: f64,
    params: &SinkhornParams,
) -> Result<SinkhornSolution> {
    let n = a.len();
    let m = b.len();
    if cost.rows() != n || cost.cols() != m {
        return Err(Error::Dimension(format!(
            "cost {}x{} vs a[{n}], b[{m}]",
            cost.rows(),
            cost.cols()
        )));
    }
    if eps <= 0.0 {
        return Err(Error::InvalidParam("eps must be positive".into()));
    }
    let log_a: Vec<f64> =
        a.iter().map(|&x| if x > 0.0 { x.ln() } else { f64::NEG_INFINITY }).collect();
    let log_b: Vec<f64> =
        b.iter().map(|&x| if x > 0.0 { x.ln() } else { f64::NEG_INFINITY }).collect();
    let cost_t = cost.transpose();
    let mut alpha = vec![0.0; n];
    let mut beta = vec![0.0; m];
    let mut displacement = f64::INFINITY;
    let mut iters = 0;
    let mut converged = false;
    while iters < params.max_iters {
        iters += 1;
        // alpha update: alpha_i = eps(log a_i - lse_j((-C_ij + beta_j)/eps))
        let beta_ref = &beta;
        let new_alpha: Vec<f64> = pool::parallel_map(n, |i| {
            let lse = row_lse(cost.row(i), beta_ref, eps);
            if log_a[i] == f64::NEG_INFINITY || lse == f64::NEG_INFINITY {
                f64::NEG_INFINITY
            } else {
                eps * (log_a[i] - lse)
            }
        });
        let alpha_ref = &new_alpha;
        let new_beta: Vec<f64> = pool::parallel_map(m, |j| {
            let lse = row_lse(cost_t.row(j), alpha_ref, eps);
            if log_b[j] == f64::NEG_INFINITY || lse == f64::NEG_INFINITY {
                f64::NEG_INFINITY
            } else {
                eps * (log_b[j] - lse)
            }
        });
        // Displacement in POTENTIAL space scaled to the u/v metric:
        // |e^{alpha/eps} - e^{alpha'/eps}| is not stable; use the dual
        // displacement (sup-norm of potential change) instead.
        displacement = alpha
            .iter()
            .zip(&new_alpha)
            .chain(beta.iter().zip(&new_beta))
            .map(|(x, y)| {
                if x.is_finite() && y.is_finite() {
                    (x - y).abs()
                } else {
                    0.0
                }
            })
            .fold(0.0f64, f64::max);
        alpha = new_alpha;
        beta = new_beta;
        if displacement <= params.delta * eps.max(1e-12) {
            converged = true;
            break;
        }
    }
    if !converged && params.strict {
        return Err(Error::NotConverged { iters, err: displacement });
    }
    // Objective from the log-domain plan: T_ij = exp((alpha_i + beta_j - C_ij)/eps).
    let alpha_ref = &alpha;
    let beta_ref = &beta;
    let (transport, entropy) = pool::parallel_fold(
        n,
        |start, end| {
            let mut tr = 0.0;
            let mut en = Vec::new();
            for i in start..end {
                if alpha_ref[i] == f64::NEG_INFINITY {
                    continue;
                }
                let crow = cost.row(i);
                for j in 0..m {
                    if !crow[j].is_finite() || beta_ref[j] == f64::NEG_INFINITY {
                        continue;
                    }
                    let t = ((alpha_ref[i] + beta_ref[j] - crow[j]) / eps).exp();
                    if t > 0.0 {
                        tr += t * crow[j];
                        en.push(t);
                    }
                }
            }
            (tr, plan_entropy(en.into_iter()))
        },
        |x, y| (x.0 + y.0, x.1 + y.1),
        (0.0, 0.0),
    );
    let objective = transport - eps * entropy;
    if !objective.is_finite() {
        return Err(Error::Numerical("log-domain objective is not finite".into()));
    }
    // Return the scalings for API parity (may overflow to inf for tiny
    // eps; the potentials are what is numerically meaningful).
    let u: Vec<f64> = alpha.iter().map(|&x| (x / eps).exp()).collect();
    let v: Vec<f64> = beta.iter().map(|&x| (x / eps).exp()).collect();
    Ok(SinkhornSolution { u, v, objective, iterations: iters, displacement, converged })
}

/// Log-domain Sinkhorn for entropic UOT (Algorithm 2 on the dual
/// potentials): the scaling exponent `ρ = λ/(λ+ε)` multiplies the
/// potential updates, and the Eq. 10 objective — transport, entropy and
/// both KL marginal penalties — is evaluated from the log-plan
/// `ln T_ij = (α_i + β_j − C_ij)/ε` without ever forming a kernel entry.
/// This is the dense engine behind a `LogDomain` backend override (or an
/// `Auto` escalation) on unbalanced problems.
pub fn log_sinkhorn_uot(
    cost: &Mat,
    a: &[f64],
    b: &[f64],
    lambda: f64,
    eps: f64,
    params: &SinkhornParams,
) -> Result<SinkhornSolution> {
    let n = a.len();
    let m = b.len();
    if cost.rows() != n || cost.cols() != m {
        return Err(Error::Dimension(format!(
            "cost {}x{} vs a[{n}], b[{m}]",
            cost.rows(),
            cost.cols()
        )));
    }
    if lambda <= 0.0 || eps <= 0.0 {
        return Err(Error::InvalidParam(format!(
            "lambda ({lambda}) and eps ({eps}) must be positive"
        )));
    }
    let rho = crate::ot::uot::uot_rho(lambda, eps);
    let log_a: Vec<f64> =
        a.iter().map(|&x| if x > 0.0 { x.ln() } else { f64::NEG_INFINITY }).collect();
    let log_b: Vec<f64> =
        b.iter().map(|&x| if x > 0.0 { x.ln() } else { f64::NEG_INFINITY }).collect();
    let cost_t = cost.transpose();
    let mut alpha = vec![0.0; n];
    let mut beta = vec![0.0; m];
    let mut displacement = f64::INFINITY;
    let mut iters = 0;
    let mut converged = false;
    while iters < params.max_iters {
        iters += 1;
        // alpha_i = rho * eps * (log a_i - lse_j((-C_ij + beta_j)/eps)),
        // the potential-space image of u = (a ./ K v)^rho.
        let beta_ref = &beta;
        let new_alpha: Vec<f64> = pool::parallel_map(n, |i| {
            let lse = row_lse(cost.row(i), beta_ref, eps);
            if log_a[i] == f64::NEG_INFINITY || lse == f64::NEG_INFINITY {
                f64::NEG_INFINITY
            } else {
                rho * eps * (log_a[i] - lse)
            }
        });
        let alpha_ref = &new_alpha;
        let new_beta: Vec<f64> = pool::parallel_map(m, |j| {
            let lse = row_lse(cost_t.row(j), alpha_ref, eps);
            if log_b[j] == f64::NEG_INFINITY || lse == f64::NEG_INFINITY {
                f64::NEG_INFINITY
            } else {
                rho * eps * (log_b[j] - lse)
            }
        });
        displacement = alpha
            .iter()
            .zip(&new_alpha)
            .chain(beta.iter().zip(&new_beta))
            .map(|(x, y)| if x.is_finite() && y.is_finite() { (x - y).abs() } else { 0.0 })
            .fold(0.0f64, f64::max);
        alpha = new_alpha;
        beta = new_beta;
        if displacement <= params.delta * eps.max(1e-12) {
            converged = true;
            break;
        }
    }
    if !converged && params.strict {
        return Err(Error::NotConverged { iters, err: displacement });
    }
    // Eq. 10 from the log-plan: transport + entropy over entries, KL
    // penalties from the plan marginals (safe in the linear domain —
    // entries are bounded by the marginal masses after a scaling pass).
    let alpha_ref = &alpha;
    let beta_ref = &beta;
    let (transport, entropy, row_marg, col_marg) = pool::parallel_fold(
        n,
        |start, end| {
            let mut tr = 0.0;
            let mut en = 0.0;
            let mut row = vec![0.0; n];
            let mut col = vec![0.0; m];
            for i in start..end {
                if alpha_ref[i] == f64::NEG_INFINITY {
                    continue;
                }
                let crow = cost.row(i);
                for j in 0..m {
                    if !crow[j].is_finite() || beta_ref[j] == f64::NEG_INFINITY {
                        continue;
                    }
                    let lt = (alpha_ref[i] + beta_ref[j] - crow[j]) / eps;
                    let t = lt.exp();
                    if t > 0.0 {
                        tr += t * crow[j];
                        en -= t * (lt - 1.0);
                        row[i] += t;
                        col[j] += t;
                    }
                }
            }
            (tr, en, row, col)
        },
        |(tr_a, en_a, mut row_a, mut col_a), (tr_b, en_b, row_b, col_b)| {
            for (x, y) in row_a.iter_mut().zip(row_b) {
                *x += y;
            }
            for (x, y) in col_a.iter_mut().zip(col_b) {
                *x += y;
            }
            (tr_a + tr_b, en_a + en_b, row_a, col_a)
        },
        (0.0, 0.0, vec![0.0; n], vec![0.0; m]),
    );
    let objective = transport - eps * entropy
        + lambda * kl_divergence(&row_marg, a)
        + lambda * kl_divergence(&col_marg, b);
    if !objective.is_finite() {
        return Err(Error::Numerical("log-domain UOT objective is not finite".into()));
    }
    let u: Vec<f64> = alpha.iter().map(|&x| (x / eps).exp()).collect();
    let v: Vec<f64> = beta.iter().map(|&x| (x / eps).exp()).collect();
    Ok(SinkhornSolution { u, v, objective, iterations: iters, displacement, converged })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ot::cost::{gibbs_kernel, sq_euclidean_cost};
    use crate::ot::sinkhorn::sinkhorn_ot;
    use crate::rng::Rng;

    fn problem(n: usize, seed: u64) -> (Mat, Vec<f64>, Vec<f64>) {
        let mut rng = Rng::seed_from(seed);
        let pts: Vec<Vec<f64>> = (0..n)
            .map(|_| (0..2).map(|_| rng.uniform()).collect())
            .collect();
        let cost = sq_euclidean_cost(&pts, &pts);
        let a: Vec<f64> = (0..n).map(|_| rng.uniform() + 0.1).collect();
        let sa: f64 = a.iter().sum();
        let b: Vec<f64> = (0..n).map(|_| rng.uniform() + 0.1).collect();
        let sb: f64 = b.iter().sum();
        (
            cost,
            a.iter().map(|x| x / sa).collect(),
            b.iter().map(|x| x / sb).collect(),
        )
    }

    #[test]
    fn matches_multiplicative_sinkhorn_at_moderate_eps() {
        let (cost, a, b) = problem(40, 201);
        let eps = 0.1;
        let kernel = gibbs_kernel(&cost, eps);
        let classic =
            sinkhorn_ot(&kernel, &cost, &a, &b, eps, &SinkhornParams::default()).unwrap();
        let logd = log_sinkhorn_ot(
            &cost,
            &a,
            &b,
            eps,
            &SinkhornParams { delta: 1e-10, max_iters: 5000, strict: false },
        )
        .unwrap();
        let rel = (classic.objective - logd.objective).abs() / classic.objective.abs();
        assert!(rel < 1e-4, "classic {} vs log {}", classic.objective, logd.objective);
    }

    #[test]
    fn survives_tiny_eps_where_multiplicative_underflows() {
        let (cost, a, b) = problem(24, 203);
        let eps = 1e-4; // K = exp(-C/eps) underflows to all-zero rows
        let logd = log_sinkhorn_ot(
            &cost,
            &a,
            &b,
            eps,
            &SinkhornParams { delta: 1e-8, max_iters: 20000, strict: false },
        )
        .unwrap();
        assert!(logd.objective.is_finite());
        // At eps -> 0 the entropic objective approaches the unregularized
        // OT cost, which is non-negative for a metric cost.
        assert!(logd.objective > -1e-6, "objective {}", logd.objective);
    }

    #[test]
    fn plan_marginals_hold_in_log_domain() {
        let (cost, a, b) = problem(24, 207);
        let eps = 0.05;
        let sol = log_sinkhorn_ot(
            &cost,
            &a,
            &b,
            eps,
            &SinkhornParams { delta: 1e-11, max_iters: 10000, strict: false },
        )
        .unwrap();
        assert!(sol.converged);
        // Reconstruct row marginals via potentials.
        for i in (0..24).step_by(5) {
            let alpha_i = sol.u[i].ln() * eps;
            let mut row = 0.0;
            for j in 0..24 {
                let beta_j = sol.v[j].ln() * eps;
                row += ((alpha_i + beta_j - cost.get(i, j)) / eps).exp();
            }
            assert!((row - a[i]).abs() < 1e-5, "row {i}: {row} vs {}", a[i]);
        }
    }

    #[test]
    fn rejects_bad_input() {
        let (cost, a, b) = problem(8, 209);
        assert!(log_sinkhorn_ot(&cost, &a, &b, 0.0, &SinkhornParams::default()).is_err());
        assert!(log_sinkhorn_ot(&cost, &a[..4], &b, 0.1, &SinkhornParams::default()).is_err());
    }

    #[test]
    fn uot_matches_multiplicative_at_moderate_eps() {
        let (cost, a, b) = problem(24, 211);
        // Unbalance the masses (paper setting 5 vs 3).
        let a: Vec<f64> = a.iter().map(|x| x * 5.0).collect();
        let b: Vec<f64> = b.iter().map(|x| x * 3.0).collect();
        let (lambda, eps) = (1.0, 0.1);
        let kernel = gibbs_kernel(&cost, eps);
        let params = SinkhornParams { delta: 1e-10, max_iters: 5000, strict: false };
        let classic =
            crate::ot::uot::sinkhorn_uot(&kernel, &cost, &a, &b, lambda, eps, &params).unwrap();
        let logd = log_sinkhorn_uot(&cost, &a, &b, lambda, eps, &params).unwrap();
        let rel = (classic.objective - logd.objective).abs() / classic.objective.abs();
        assert!(rel < 1e-6, "classic {} vs log {}", classic.objective, logd.objective);
    }

    #[test]
    fn uot_survives_tiny_eps() {
        let (cost, a, b) = problem(20, 213);
        let a: Vec<f64> = a.iter().map(|x| x * 2.0).collect();
        let eps = 1e-4; // multiplicative kernel underflows to all-zero rows
        let sol = log_sinkhorn_uot(
            &cost,
            &a,
            &b,
            1.0,
            eps,
            &SinkhornParams { delta: 1e-8, max_iters: 5000, strict: false },
        )
        .unwrap();
        assert!(sol.objective.is_finite());
        assert!(sol.objective >= 0.0, "objective {}", sol.objective);
    }

    #[test]
    fn uot_rejects_bad_params() {
        let (cost, a, b) = problem(8, 217);
        let p = SinkhornParams::default();
        assert!(log_sinkhorn_uot(&cost, &a, &b, 0.0, 0.1, &p).is_err());
        assert!(log_sinkhorn_uot(&cost, &a, &b, 1.0, 0.0, &p).is_err());
        assert!(log_sinkhorn_uot(&cost, &a[..4], &b, 1.0, 0.1, &p).is_err());
    }
}
