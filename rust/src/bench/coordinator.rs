//! `repro bench coordinator`: throughput/latency of the sharded
//! distance service on the paper's echocardiogram pairwise workload
//! (Section 6 shape: all frames on one shared pixel grid, an ε sweep
//! giving the router several cost fingerprints to spread).
//!
//! For each shard count the harness runs the SAME job list twice on one
//! service: a COLD pass (first submission — every fingerprint builds
//! its cost/kernel artifacts) and a WARM pass (identical resubmission —
//! every job is an artifact-cache hit), reporting jobs/sec per pass
//! plus the snapshot p99 and cache/steal counters. Results are
//! placement-independent, so every configuration returns bitwise-equal
//! distances — the rows differ only in time.

use std::sync::Arc;
use std::time::Instant;

use crate::coordinator::{
    CoordinatorConfig, DistanceJob, DistanceService, Measure, Method, ProblemSpec,
};
use crate::data::echo::{downsample_frames, generate, EchoConfig, Health};
use crate::rng::Rng;
use crate::util::json::Json;

/// Workload + pool parameters for one coordinator bench run.
#[derive(Clone, Debug)]
pub struct BenchConfig {
    /// Pixel-grid side (each measure has `size²` support points).
    pub size: usize,
    /// Frames generated per video (downsampled 3:1 before pairing).
    pub frames: usize,
    /// Worker threads of the service under test.
    pub workers: usize,
    /// Shard counts to compare (the ISSUE's 1-vs-N contrast).
    pub shard_counts: Vec<usize>,
    /// ε sweep: one artifact fingerprint per value, so the router has
    /// several affinity classes to spread across shards.
    pub eps_values: Vec<f64>,
    /// Work stealing on the services under test.
    pub steal: bool,
}

impl BenchConfig {
    /// A minutes-scale configuration for the committed artifact.
    pub fn quick(workers: usize) -> Self {
        BenchConfig {
            size: 24,
            frames: 18,
            workers,
            shard_counts: vec![1, workers.max(2)],
            eps_values: vec![0.05, 0.1],
            steal: true,
        }
    }
}

/// The echocardiogram pairwise job list: every kept frame against every
/// later one, per ε. All measures share ONE grid `Arc`, so jobs of one
/// ε share one fingerprint (maximal artifact reuse, maximal routing
/// skew — the stealing stress case). Deterministic in its arguments —
/// public because it is ALSO the replay workload of the gateway load
/// generator ([`crate::net`] loadgen and `repro bench gateway`), so
/// serving benchmarks and coordinator benchmarks stress the same jobs.
pub fn pairwise_jobs(size: usize, frames: usize, eps_values: &[f64]) -> Vec<DistanceJob> {
    let mut rng = Rng::seed_from(7);
    let video = generate(
        &EchoConfig { size, frames, period: 12.0, health: Health::Normal, noise: 0.01 },
        &mut rng,
    );
    let keep = downsample_frames(&video, 3);
    let grid: Arc<Vec<Vec<f64>>> = Arc::new(
        (0..size * size).map(|k| vec![(k % size) as f64, (k / size) as f64]).collect(),
    );
    let measures: Vec<Measure> = keep
        .iter()
        .map(|&i| {
            let frame = &video.frames[i];
            let total: f64 = frame.iter().map(|v| v.max(0.0)).sum();
            let mass: Vec<f64> =
                frame.iter().map(|v| v.max(0.0) / total.max(f64::MIN_POSITIVE)).collect();
            Measure { points: grid.clone(), mass: Arc::new(mass) }
        })
        .collect();
    let mut jobs = Vec::new();
    let mut id = 0u64;
    for &eps in eps_values {
        for i in 0..measures.len() {
            for j in (i + 1)..measures.len() {
                jobs.push(DistanceJob {
                    id,
                    source: measures[i].clone(),
                    target: measures[j].clone(),
                    method: Method::SparSink,
                    spec: ProblemSpec { eta: size as f64 / 7.5, eps, ..Default::default() },
                    seed: id,
                });
                id += 1;
            }
        }
    }
    jobs
}

/// Run the bench and return the `BENCH_coordinator.json` document. Also
/// prints one line per row. Latency/steal fields are cumulative
/// service-lifetime snapshots at the end of each pass (the histogram
/// cannot be reset); the cache fields are per-pass deltas.
pub fn run(cfg: &BenchConfig) -> Json {
    let jobs = pairwise_jobs(cfg.size, cfg.frames, &cfg.eps_values);
    let mut rows = Vec::new();
    for &shards in &cfg.shard_counts {
        let service = DistanceService::start(CoordinatorConfig {
            workers: cfg.workers,
            shards,
            steal: cfg.steal,
            ..Default::default()
        });
        let (mut prev_hits, mut prev_misses) = (0u64, 0u64);
        for pass in ["cold", "warm"] {
            let t0 = Instant::now();
            let results = service.submit_all(jobs.clone()).expect("bench service alive");
            let wall = t0.elapsed();
            let failed = results.iter().filter(|r| r.error.is_some()).count();
            let m = service.metrics();
            let stolen: u64 = m.shards.iter().map(|s| s.stolen).sum();
            let jobs_per_sec = jobs.len() as f64 / wall.as_secs_f64().max(1e-9);
            println!(
                "coordinator bench: shards {shards} {pass}: {} jobs in {wall:.2?} \
                 ({jobs_per_sec:.1} jobs/s, p99 {:.1?}, cache {}h/{}m, stolen {stolen})",
                jobs.len(),
                m.p99_latency,
                m.cache.hits - prev_hits,
                m.cache.misses - prev_misses,
            );
            rows.push(Json::obj(vec![
                ("shards", Json::num(shards as f64)),
                ("pass", Json::str(pass)),
                ("jobs", Json::num(jobs.len() as f64)),
                ("failed", Json::num(failed as f64)),
                ("wall_ms", Json::num(wall.as_secs_f64() * 1e3)),
                ("jobs_per_sec", Json::num(jobs_per_sec)),
                ("p99_us_cumulative", Json::num(m.p99_latency.as_micros() as f64)),
                ("cache_hits", Json::num((m.cache.hits - prev_hits) as f64)),
                ("cache_misses", Json::num((m.cache.misses - prev_misses) as f64)),
                ("stolen_cumulative", Json::num(stolen as f64)),
            ]));
            prev_hits = m.cache.hits;
            prev_misses = m.cache.misses;
        }
        service.shutdown();
    }
    let pairs = jobs.len() / cfg.eps_values.len().max(1);
    Json::obj(vec![
        ("bench", Json::str("coordinator")),
        (
            "workload",
            Json::obj(vec![
                ("grid", Json::num(cfg.size as f64)),
                ("frame_pairs", Json::num(pairs as f64)),
                (
                    "eps_values",
                    Json::arr(cfg.eps_values.iter().map(|&e| Json::num(e)).collect()),
                ),
                ("jobs_per_pass", Json::num(jobs.len() as f64)),
                ("workers", Json::num(cfg.workers as f64)),
                ("steal", Json::Bool(cfg.steal)),
                ("method", Json::str(Method::SparSink.name())),
            ]),
        ),
        ("rows", Json::arr(rows)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeSet;

    #[test]
    fn workload_is_deterministic_and_fingerprint_shaped() {
        let cfg = BenchConfig { size: 8, frames: 9, ..BenchConfig::quick(2) };
        let a = pairwise_jobs(cfg.size, cfg.frames, &cfg.eps_values);
        let b = pairwise_jobs(cfg.size, cfg.frames, &cfg.eps_values);
        assert!(!a.is_empty());
        assert_eq!(a.len(), b.len());
        // Deterministic workload: same ids, seeds and masses both times.
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.seed, y.seed);
            assert_eq!(x.source.mass, y.source.mass);
        }
        // One shared grid Arc per run: every job aliases the same points.
        assert!(Arc::ptr_eq(&a[0].source.points, &a[a.len() - 1].target.points));
        // One ε class per eps value.
        let eps: BTreeSet<u64> = a.iter().map(|j| j.spec.eps.to_bits()).collect();
        assert_eq!(eps.len(), cfg.eps_values.len());
    }

    #[test]
    fn tiny_bench_run_produces_rows() {
        let cfg = BenchConfig {
            size: 6,
            frames: 6,
            workers: 2,
            shard_counts: vec![1, 2],
            eps_values: vec![0.1],
            steal: true,
        };
        let doc = run(&cfg);
        let rows = doc.get("rows").expect("rows").items();
        // One cold + one warm row per shard count.
        assert_eq!(rows.len(), 4);
        for row in rows {
            assert_eq!(row.get("failed").and_then(Json::as_f64), Some(0.0));
            assert!(row.get("jobs_per_sec").and_then(Json::as_f64).unwrap() > 0.0);
        }
        // The warm pass re-hits what the cold pass built.
        assert!(rows[1].get("cache_hits").and_then(Json::as_f64).unwrap() > 0.0);
        assert_eq!(rows[1].get("cache_misses").and_then(Json::as_f64), Some(0.0));
    }
}
