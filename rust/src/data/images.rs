//! Synthetic RGB point clouds for the color-transfer application
//! (Appendix D.1, Fig. 13; DESIGN.md §3 documents the substitution for
//! the ocean photographs).
//!
//! * "daytime" — colors concentrated around sky-blue and sea-blue modes
//!   with a white-foam tail;
//! * "sunset"  — warm orange/red modes with a dark-sea tail.
//!
//! Each cloud is `n` RGB triples in [0,1]³ with uniform weights, exactly
//! the structure of the downsampled-pixel clouds in the paper.

use crate::rng::Rng;

/// A named color mode: mean RGB + isotropic spread + weight.
struct Mode {
    mean: [f64; 3],
    sd: f64,
    weight: f64,
}

fn sample_cloud(modes: &[Mode], n: usize, rng: &mut Rng) -> Vec<Vec<f64>> {
    let weights: Vec<f64> = modes.iter().map(|m| m.weight).collect();
    (0..n)
        .map(|_| {
            let k = rng.weighted_choice(&weights);
            let m = &modes[k];
            (0..3)
                .map(|c| (m.mean[c] + m.sd * rng.normal()).clamp(0.0, 1.0))
                .collect()
        })
        .collect()
}

/// Daytime ocean palette.
pub fn daytime_cloud(n: usize, rng: &mut Rng) -> Vec<Vec<f64>> {
    sample_cloud(
        &[
            Mode { mean: [0.45, 0.7, 0.95], sd: 0.06, weight: 0.45 }, // sky
            Mode { mean: [0.1, 0.35, 0.6], sd: 0.07, weight: 0.4 },   // sea
            Mode { mean: [0.9, 0.93, 0.95], sd: 0.04, weight: 0.15 }, // foam/cloud
        ],
        n,
        rng,
    )
}

/// Sunset ocean palette.
pub fn sunset_cloud(n: usize, rng: &mut Rng) -> Vec<Vec<f64>> {
    sample_cloud(
        &[
            Mode { mean: [0.95, 0.55, 0.2], sd: 0.07, weight: 0.4 }, // orange sky
            Mode { mean: [0.8, 0.25, 0.2], sd: 0.06, weight: 0.3 },  // red sun band
            Mode { mean: [0.2, 0.12, 0.25], sd: 0.05, weight: 0.3 }, // dark sea
        ],
        n,
        rng,
    )
}

/// Apply a barycentric-projection color map from a transport plan:
/// each source color moves to the plan-weighted average of the target
/// colors it couples with (the standard OT color-transfer map used by
/// Ferradans et al.).
pub fn barycentric_map(
    plan_row: impl Fn(usize) -> Vec<(usize, f64)>,
    targets: &[Vec<f64>],
    n_source: usize,
) -> Vec<Vec<f64>> {
    (0..n_source)
        .map(|i| {
            let row = plan_row(i);
            let mass: f64 = row.iter().map(|(_, t)| t).sum();
            if mass <= 0.0 {
                return vec![0.0; 3];
            }
            let mut out = vec![0.0; 3];
            for (j, t) in row {
                for c in 0..3 {
                    out[c] += t * targets[j][c];
                }
            }
            out.iter_mut().for_each(|x| *x /= mass);
            out
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clouds_are_in_rgb_cube() {
        let mut rng = Rng::seed_from(117);
        for cloud in [daytime_cloud(500, &mut rng), sunset_cloud(500, &mut rng)] {
            assert_eq!(cloud.len(), 500);
            assert!(cloud.iter().flatten().all(|&v| (0.0..=1.0).contains(&v)));
        }
    }

    #[test]
    fn palettes_are_distinct() {
        let mut rng = Rng::seed_from(119);
        let day = daytime_cloud(2000, &mut rng);
        let sun = sunset_cloud(2000, &mut rng);
        // Mean red channel: sunset is much warmer.
        let mean_r = |c: &[Vec<f64>]| c.iter().map(|p| p[0]).sum::<f64>() / c.len() as f64;
        let mean_b = |c: &[Vec<f64>]| c.iter().map(|p| p[2]).sum::<f64>() / c.len() as f64;
        assert!(mean_r(&sun) > mean_r(&day) + 0.2);
        assert!(mean_b(&day) > mean_b(&sun) + 0.2);
    }

    #[test]
    fn barycentric_map_averages_targets() {
        let targets = vec![vec![1.0, 0.0, 0.0], vec![0.0, 1.0, 0.0]];
        let mapped = barycentric_map(
            |_| vec![(0, 0.25), (1, 0.75)],
            &targets,
            2,
        );
        for m in mapped {
            assert!((m[0] - 0.25).abs() < 1e-12);
            assert!((m[1] - 0.75).abs() < 1e-12);
        }
    }

    #[test]
    fn barycentric_map_handles_empty_rows() {
        let targets = vec![vec![0.5, 0.5, 0.5]];
        let mapped = barycentric_map(|_| vec![], &targets, 1);
        assert_eq!(mapped[0], vec![0.0; 3]);
    }
}
