//! From-scratch CLI argument parser (the offline image has no `clap`).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value`, and
//! positional arguments, with generated usage text. Repeated options
//! are rejected loudly: a silent last-wins `--s 2 --s 5` once masked a
//! mistyped sweep, so [`Args::parse`] returns an error instead.

use std::collections::HashMap;

/// Parsed command line.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// First positional token (subcommand).
    pub command: Option<String>,
    /// Remaining positionals.
    pub positional: Vec<String>,
    /// `--key value` / `--key=value` options.
    pub options: HashMap<String, String>,
    /// Bare `--flag`s.
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of tokens (usually `std::env::args().skip(1)`).
    /// `value_keys` lists options that consume the following token.
    ///
    /// A repeated option (`--s 2 --s 5`, in either `--key value` or
    /// `--key=value` form) is an error: silently keeping the last
    /// value hides typos in long invocations. Repeated bare flags are
    /// idempotent and stay accepted.
    pub fn parse(
        tokens: impl IntoIterator<Item = String>,
        value_keys: &[&str],
    ) -> Result<Args, String> {
        let mut args = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.insert_option(k, v.to_string())?;
                } else if value_keys.contains(&stripped)
                    && it.peek().map(|n| !n.starts_with("--")).unwrap_or(false)
                {
                    match it.next() {
                        Some(v) => args.insert_option(stripped, v)?,
                        None => args.flags.push(stripped.to_string()),
                    }
                } else {
                    args.flags.push(stripped.to_string());
                }
            } else if args.command.is_none() {
                args.command = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    /// Record `--key value`, rejecting a second occurrence of `key`.
    fn insert_option(&mut self, key: &str, value: String) -> Result<(), String> {
        match self.options.insert(key.to_string(), value) {
            None => Ok(()),
            Some(previous) => Err(format!(
                "duplicate option '--{key}' (already given '{previous}'); \
                 pass each option at most once"
            )),
        }
    }

    /// Whether bare `--name` was passed.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// The value of `--key value` / `--key=value`, if present.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    /// Parse an option's value, falling back to `default` when absent
    /// or unparsable.
    pub fn get_parsed<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

/// Usage text for the `repro` binary.
pub fn usage() -> String {
    "repro — Spar-Sink reproduction driver\n\
     \n\
     USAGE:\n\
       repro <COMMAND> [OPTIONS]\n\
     \n\
     COMMANDS:\n\
       experiment <id|all> [--full] [--out results/]   regenerate a paper figure/table\n\
       solve --problem ot|uot|barycenter [--n N] [--d D] [--eps E] [--lambda L]\n\
             [--s MULT] [--method M] [--backend B] [--seed S]\n\
             one-off synthetic solve; dispatches through api::solve_batch —\n\
             the dense cost (square or rectangular) is upgraded to a shared\n\
             artifact in the global cache, so the exact reference and the\n\
             approx run share one kernel build; prints the cache counters\n\
             (hits/misses/evictions, resident entries + in-flight builds,\n\
             bytes vs budget) after both solves\n\
       serve [--videos V] [--frames F] [--workers W] [--shards S] [--no-steal]\n\
             [--method M] [--eps E] [--backend B] [--threshold T] [--shared-grid]\n\
             run the batched WFR distance service; --shared-grid keeps\n\
             every frame on the full pixel grid so all pairwise jobs\n\
             share one support and the coordinator's artifact cache\n\
             builds cost/kernel once per (eta, eps) — workers racing a\n\
             build coalesce on its single-flight slot, distinct (eta,\n\
             eps) builds overlap, and the final metrics include the full\n\
             cache gauge line (hits / misses / evictions, resident\n\
             entries, `building` = in-flight builds, bytes vs budget);\n\
             --threshold T (default 0.05) is the per-frame support\n\
             cutoff when --shared-grid is NOT set (pixels below T of\n\
             the frame max are dropped, so each frame gets its own\n\
             support and cache sharing across frames is incidental);\n\
             --workers/--shards take 0 = available parallelism (shards\n\
             clamp to the worker count), --no-steal disables work\n\
             stealing — batches are routed to shards by their cost\n\
             fingerprint, so placement never changes results\n\
       serve --port P [--addr A] [--workers W] [--shards S] [--no-steal]\n\
             [--duration SECS]\n\
             gateway mode: serve the coordinator over HTTP/1.1 instead of\n\
             running the echo demo (default addr 127.0.0.1, port 8517;\n\
             --port 0 lets the OS pick). Endpoints: POST /solve and\n\
             POST /barycenter take JSON jobs and answer the solved result\n\
             (bitwise-identical to an in-process submission), GET /metrics\n\
             serves the Prometheus text exposition (spar_sink_* families\n\
             incl. per-shard and cache gauges), GET /healthz answers\n\
             200 ok / 503 draining. Admission control instead of stalls:\n\
             a full submission queue answers 429 Too Many Requests with\n\
             retry-after, the connection cap answers 503. --duration SECS\n\
             drains after SECS (in-flight jobs complete, new connections\n\
             are refused) and prints the final metrics; default runs\n\
             until killed\n\
       balance --backends A,B,... [--port P] [--addr H] [--duration SECS]\n\
             fingerprint-affine load balancer over N gateway backends\n\
             (start each with `serve --port`): jobs route by their cost\n\
             fingerprint so one ε class keeps hitting one backend's\n\
             artifact cache, fingerprint-less jobs round-robin, and\n\
             bodies relay verbatim in both directions — results through\n\
             the balancer are bitwise-identical to a direct submission.\n\
             /healthz probes evict dead backends and re-admit recovered\n\
             ones; 429/503 answers retry within a bounded budget\n\
             (honoring retry-after), and budget exhaustion is a loud\n\
             503, never a hang. GET /metrics serves per-backend\n\
             spar_sink_balancer_* families\n\
       bench coordinator [--workers W] [--shards N] [--size G] [--frames F]\n\
             [--no-steal] [--out FILE]\n\
             sharded-service throughput/latency on the echocardiogram\n\
             pairwise workload: 1 vs N shards, cold vs warm artifact\n\
             cache; writes BENCH_coordinator.json (or FILE)\n\
       bench kernels [--quick] [--eps E] [--s MULT] [--out FILE]\n\
             kernel-level hot-loop n-sweep: tiled dense cost/Gibbs\n\
             builders, sparse row/col log-sum-exp, fused multiplicative\n\
             vs log-domain scaling at fixed iterations, and end-to-end\n\
             sinkhorn vs spar-sink vs spar-sink-log solves; writes\n\
             BENCH_kernels.json (or FILE). --quick runs the CI\n\
             seconds-scale smoke sweep\n\
       bench gateway [--quick] [--workers W] [--jobs N] [--clients C]\n\
             [--size G] [--out FILE]\n\
             serving throughput/latency via the replay load generator:\n\
             loadgen drives the echocardiogram pairwise workload at a\n\
             direct gateway, at a balancer over 1 and 2 backends, and\n\
             at a deliberately starved backend (nonzero 429 rate), and\n\
             reports throughput, 429 rate, and p50/p99 per scenario;\n\
             writes BENCH_gateway.json (or FILE)\n\
       lint [--root DIR] [--config FILE] [--list-rules]\n\
             repo-native static contract checks over the rust/src tree\n\
             (README \"Static contracts\"): budget-convention (every\n\
             sampling budget goes through solvers::sketch_budget),\n\
             unordered-iter (no HashMap/HashSet iteration feeding ids,\n\
             batches, fingerprints, or rendered output), wall-clock (no\n\
             Instant/SystemTime/available_parallelism in result-affecting\n\
             modules), lock-unwrap (worker paths use\n\
             util::sync::lock_unpoisoned), lint-pragma (every\n\
             `// lint: allow(rule, \"reason\")` carries a reason and still\n\
             suppresses something). Exits nonzero on any finding;\n\
             per-rule allowlists live in lint.toml at the repo root\n\
       runtime-info                                    PJRT platform + artifact menu (xla feature)\n\
       list                                            list available experiments\n\
     \n\
     OPTIONS:\n\
       --full        paper-scale parameters (default: quick profile)\n\
       --out DIR     also write JSON rows to DIR/<id>.json\n\
       --s MULT      sketch budget multiplier (default 8): every sketch\n\
                     solver samples s = MULT * s0(max(n, m)) expected\n\
                     entries, s0(n) = 1e-3 n ln^4 n\n\
       --method M    any solver registered in the unified API:\n\
                     sinkhorn|spar-sink|spar-sink-log|rand-sink|nys-sink|\n\
                     greenkhorn|screenkhorn|spar-ibp\n\
                     (solve and serve dispatch through api::solve; methods\n\
                     that do not support the requested formulation report\n\
                     a per-job error)\n\
       --backend B   scaling-loop override: auto|multiplicative|log-domain,\n\
                     valid for every formulation — balanced/unbalanced OT,\n\
                     dense sinkhorn, and barycenters (spar-ibp included).\n\
                     Defaults per method: the backend-switched solvers use\n\
                     auto (multiplicative above the eps threshold, log-domain\n\
                     below it or on numerical failure/collapse; see\n\
                     `experiment smalleps`); rand-sink stays the\n\
                     multiplicative baseline unless overridden\n\
     \n\
     Each option may be passed at most once; a repeated option is an\n\
     error rather than a silent last-wins.\n\
     \n\
     ENVIRONMENT:\n\
       SPAR_SINK_CACHE_BYTES   byte budget of the global artifact cache\n\
                               (default 512 MiB); the coordinator's cache\n\
                               is sized by CoordinatorConfig.cache_bytes\n\
       SPAR_SINK_THREADS       worker threads for the parallel cost/kernel\n\
                               builders (results are bit-identical at any\n\
                               thread count)\n"
        .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(tokens: &[&str]) -> Args {
        try_parse(tokens).expect("arguments parse")
    }

    fn try_parse(tokens: &[&str]) -> Result<Args, String> {
        Args::parse(
            tokens.iter().map(|s| s.to_string()),
            &[
                "out", "n", "eps", "lambda", "method", "seed", "videos", "frames", "workers",
                "problem", "s",
            ],
        )
    }

    #[test]
    fn parses_subcommand_and_flags() {
        let a = parse(&["experiment", "fig2", "--full", "--out", "results"]);
        assert_eq!(a.command.as_deref(), Some("experiment"));
        assert_eq!(a.positional, vec!["fig2"]);
        assert!(a.flag("full"));
        assert_eq!(a.get("out"), Some("results"));
    }

    #[test]
    fn parses_equals_form() {
        let a = parse(&["solve", "--eps=0.05", "--n=500"]);
        assert_eq!(a.get_parsed("eps", 0.0), 0.05);
        assert_eq!(a.get_parsed("n", 0usize), 500);
    }

    #[test]
    fn default_when_missing() {
        let a = parse(&["solve"]);
        assert_eq!(a.get_parsed("n", 123usize), 123);
        assert!(!a.flag("full"));
    }

    #[test]
    fn flag_does_not_swallow_positional() {
        let a = parse(&["experiment", "--full", "fig3"]);
        assert_eq!(a.positional, vec!["fig3"]);
    }

    #[test]
    fn duplicate_option_is_rejected() {
        let err = try_parse(&["solve", "--s", "2", "--s", "5"]).expect_err("must reject");
        assert!(err.contains("duplicate option '--s'"), "{err}");
        assert!(err.contains('2'), "must name the first value: {err}");
    }

    #[test]
    fn duplicate_equals_form_is_rejected() {
        assert!(try_parse(&["solve", "--eps=0.1", "--eps=0.2"]).is_err());
        // Mixed forms of the same key are duplicates too.
        assert!(try_parse(&["solve", "--eps", "0.1", "--eps=0.2"]).is_err());
    }

    #[test]
    fn distinct_options_and_repeated_flags_still_parse() {
        let a = parse(&["solve", "--s", "2", "--n", "100", "--full", "--full"]);
        assert_eq!(a.get_parsed("s", 0.0), 2.0);
        assert_eq!(a.get_parsed("n", 0usize), 100);
        assert!(a.flag("full"));
    }
}
