//! Appendix Fig. 11 — Wasserstein barycenter approximation error versus
//! s: IBP (truth) vs Nys-IBP, Rand-IBP and Spar-IBP, over
//! ε ∈ {5e-2, 1e-2(≈5⁰·1e-2), 5e-3}·… (paper: {5, 1, 0.2}·1e-1-ish menu,
//! we use {5e-2, 1e-2, 5e-3}) and d ∈ {5, 10, 20}.

use super::common::{normalize_cost, row};
use super::{ExperimentOutput, Profile};
use crate::data::synthetic::barycenter_measures;
use crate::linalg::Mat;
use crate::metrics::{l1_distance, mean_sd, normalized_histogram, s0};
use crate::ot::barycenter::{ibp_barycenter, ibp_barycenter_with};
use crate::ot::cost::{gibbs_kernel, sq_euclidean_cost};
use crate::ot::sinkhorn::SinkhornParams;
use crate::rng::Rng;
use crate::solvers::spar_ibp::spar_ibp;
use crate::sparse::poisson_sparsify_with;
use crate::util::json::Json;
use crate::util::table::{f, Table};

/// Rand-IBP: uniform-probability sparsification of each kernel.
fn rand_ibp(
    kernels: &[Mat],
    bs: &[Vec<f64>],
    w: &[f64],
    s: f64,
    params: &SinkhornParams,
    rng: &mut Rng,
) -> crate::error::Result<Vec<f64>> {
    let mut sketches = Vec::new();
    for kernel in kernels {
        let n2 = (kernel.rows() * kernel.cols()) as f64;
        let (sk, _) = poisson_sparsify_with(
            kernel.rows(),
            kernel.cols(),
            |i, j| kernel.get(i, j),
            |_, _| 0.0,
            |_, _| 1.0,
            n2,
            s,
            1.0,
            rng,
        )?;
        sketches.push(sk);
    }
    Ok(ibp_barycenter_with(&sketches, bs, w, params)?.q)
}

/// Nys-IBP: low-rank factor per kernel drives the IBP loop.
fn nys_ibp(
    kernels: &[Mat],
    bs: &[Vec<f64>],
    w: &[f64],
    rank: usize,
    params: &SinkhornParams,
    rng: &mut Rng,
) -> crate::error::Result<Vec<f64>> {
    use crate::linalg::nystrom_factorize;
    use crate::ot::barycenter::KernelOp;

    struct NysOp(crate::linalg::NystromFactor, usize);
    impl KernelOp for NysOp {
        fn apply(&self, x: &[f64]) -> Vec<f64> {
            self.0.matvec(x).iter().map(|&v| v.max(0.0)).collect()
        }
        fn apply_t(&self, x: &[f64]) -> Vec<f64> {
            self.0.matvec_t(x).iter().map(|&v| v.max(0.0)).collect()
        }
        fn rows(&self) -> usize {
            self.1
        }
        fn cols(&self) -> usize {
            self.1
        }
    }
    let ops: Vec<NysOp> = kernels
        .iter()
        .map(|k| {
            let n = k.rows();
            NysOp(
                nystrom_factorize(n, |i, j| k.get(i, j), rank, 1e-10, rng),
                n,
            )
        })
        .collect();
    Ok(ibp_barycenter_with(&ops, bs, w, params)?.q)
}

pub fn run(profile: Profile) -> ExperimentOutput {
    let n = profile.pick(300, 1000);
    let reps = profile.reps(3, 100);
    let dims: &[usize] = profile.pick(&[5usize][..], &[5, 10, 20][..]);
    let epss = [5e-2, 1e-2, 5e-3];
    let s_mults = [5.0, 10.0, 15.0, 20.0];
    let params = SinkhornParams { delta: 1e-7, max_iters: 1000, strict: false };

    let mut table = Table::new(&["eps", "d", "method", "s/s0", "L1 err", "se"]);
    let mut rows = Vec::new();
    let mut rng = Rng::seed_from(0xF171);
    for &eps in &epss {
        for &d in dims {
            // Shared uniform support in (0,1)^d.
            let pts: Vec<Vec<f64>> =
                (0..n).map(|_| (0..d).map(|_| rng.uniform()).collect()).collect();
            let cost = normalize_cost(&sq_euclidean_cost(&pts, &pts));
            let kernel = gibbs_kernel(&cost, eps);
            let kernels = vec![kernel.clone(), kernel.clone(), kernel];
            let bs = barycenter_measures(n, &mut rng);
            let w = vec![1.0 / 3.0; 3];
            let Ok(exact) = ibp_barycenter(&kernels, &bs, &w, &params) else { continue };
            let truth = normalized_histogram(&exact.q);

            for &s_mult in &s_mults {
                let budget = s_mult * s0(n);
                let mut spar_errs = Vec::new();
                let mut rand_errs = Vec::new();
                let mut nys_errs = Vec::new();
                for _ in 0..reps {
                    if let Ok(sol) = spar_ibp(&kernels, &bs, &w, budget, &params, &mut rng) {
                        let qn = normalized_histogram(&sol.solution.q);
                        spar_errs.push(l1_distance(&qn, &truth));
                    }
                    if let Ok(q) = rand_ibp(&kernels, &bs, &w, budget, &params, &mut rng) {
                        rand_errs.push(l1_distance(&normalized_histogram(&q), &truth));
                    }
                    let rank = ((budget / n as f64).ceil() as usize).max(1);
                    if let Ok(q) = nys_ibp(&kernels, &bs, &w, rank, &params, &mut rng) {
                        nys_errs.push(l1_distance(&normalized_histogram(&q), &truth));
                    }
                }
                for (name, errs) in [
                    ("nys-ibp", &nys_errs),
                    ("rand-ibp", &rand_errs),
                    ("spar-ibp", &spar_errs),
                ] {
                    let (mean, sd) = if errs.is_empty() {
                        (f64::NAN, 0.0)
                    } else {
                        mean_sd(errs)
                    };
                    let se = if errs.is_empty() { 0.0 } else { sd / (errs.len() as f64).sqrt() };
                    table.row(vec![
                        format!("{eps:.0e}"),
                        d.to_string(),
                        name.into(),
                        f(s_mult, 0),
                        f(mean, 4),
                        f(se, 4),
                    ]);
                    rows.push(row(vec![
                        ("eps", Json::num(eps)),
                        ("d", Json::num(d as f64)),
                        ("method", Json::str(name)),
                        ("s_mult", Json::num(s_mult)),
                        ("l1_err", Json::num(mean)),
                    ]));
                }
            }
        }
    }
    let text = format!(
        "Appendix Fig. 11 — barycenter L1 error vs s (n = {n}, {reps} reps)\n{}",
        table.render()
    );
    ExperimentOutput { id: "fig11", text, rows: Json::arr(rows) }
}
