//! PJRT runtime benchmark: the AOT `sinkhorn_block` execution (L1
//! Pallas + L2 JAX lowered to HLO) vs the native Rust dense iteration —
//! the block-size ablation noted in DESIGN.md §7.

use std::sync::Arc;

use spar_sink::bench::Bencher;
use spar_sink::data::synthetic::{instance, Scenario};
use spar_sink::experiments::common::ot_cost;
use spar_sink::ot::cost::gibbs_kernel;
use spar_sink::ot::sinkhorn::{sinkhorn_scalings, SinkhornParams};
use spar_sink::rng::Rng;
use spar_sink::runtime::{default_artifact_dir, manifest_path, ArtifactRegistry, DenseSinkhornRuntime, Entry};

fn main() {
    let dir = default_artifact_dir();
    if !manifest_path(&dir).exists() {
        println!("artifacts not built — skipping runtime bench (run `make artifacts`)");
        return;
    }
    let registry = Arc::new(ArtifactRegistry::open(&dir).expect("registry"));
    let runtime = DenseSinkhornRuntime::new(registry.clone());
    let mut bencher = Bencher::quick();

    for n in registry.sizes(Entry::SinkhornBlock) {
        let mut rng = Rng::seed_from(9);
        let inst = instance(Scenario::C1, n, 5, 1.0, 1.0, &mut rng);
        let cost = ot_cost(&inst.points);
        let eps = 0.1;
        let kernel = gibbs_kernel(&cost, eps);
        // Fixed 50 iterations for comparability.
        let iters = 50;
        bencher.bench(format!("pjrt_block/n={n}/{iters}iters"), || {
            let _ = std::hint::black_box(runtime.solve_ot(
                &kernel, &cost, &inst.a, &inst.b, eps, 0.0, iters,
            ));
        });
        bencher.bench(format!("native_dense/n={n}/{iters}iters"), || {
            let params = SinkhornParams { delta: 0.0, max_iters: iters, strict: false };
            let _ = std::hint::black_box(sinkhorn_scalings(
                &kernel, &inst.a, &inst.b, 1.0, &params,
            ));
        });
    }
    println!("\n{}", bencher.report("bench_runtime"));
}
