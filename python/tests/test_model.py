"""L2 correctness: sinkhorn_block / objectives vs references and invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def _problem(n, seed=0, eps=0.1):
    rng = np.random.default_rng(seed)
    x = rng.uniform(0.0, 1.0, (n, 2)).astype("float32")
    cost = np.asarray(ref.sqeuclid_cost_ref(jnp.asarray(x), jnp.asarray(x)))
    a = rng.uniform(0.5, 1.5, n).astype("float32")
    a /= a.sum()
    b = rng.uniform(0.5, 1.5, n).astype("float32")
    b /= b.sum()
    kmat = np.exp(-cost / eps).astype("float32")
    return (
        jnp.asarray(kmat),
        jnp.asarray(cost),
        jnp.asarray(a).reshape(n, 1),
        jnp.asarray(b).reshape(n, 1),
    )


def test_sinkhorn_block_matches_ref():
    kmat, _, a, b = _problem(32)
    u0 = jnp.ones_like(a)
    v0 = jnp.ones_like(b)
    u1, v1, err1 = model.sinkhorn_block(kmat, a, b, u0, v0, jnp.float32(1.0))
    u2, v2, err2 = ref.sinkhorn_block_ref(kmat, a, b, u0, v0, 1.0, model.BLOCK_ITERS)
    np.testing.assert_allclose(u1, u2, rtol=1e-4)
    np.testing.assert_allclose(v1, v2, rtol=1e-4)
    np.testing.assert_allclose(err1, err2, rtol=1e-3, atol=1e-6)


def test_sinkhorn_block_uot_rho():
    lam, eps = 1.0, 0.1
    rho = lam / (lam + eps)
    kmat, _, a, b = _problem(32, seed=5, eps=eps)
    u0 = jnp.ones_like(a)
    v0 = jnp.ones_like(b)
    u1, v1, _ = model.sinkhorn_block(kmat, a, b, u0, v0, jnp.float32(rho))
    u2, v2, _ = ref.sinkhorn_block_ref(kmat, a, b, u0, v0, rho, model.BLOCK_ITERS)
    np.testing.assert_allclose(u1, u2, rtol=1e-4)
    np.testing.assert_allclose(v1, v2, rtol=1e-4)


def test_converged_plan_satisfies_marginals():
    """After enough blocks, T = diag(u) K diag(v) matches the marginals."""
    kmat, _, a, b = _problem(32, seed=1)
    u = jnp.ones_like(a)
    v = jnp.ones_like(b)
    for _ in range(40):  # 400 iterations
        u, v, err = model.sinkhorn_block(kmat, a, b, u, v, jnp.float32(1.0))
        if float(err) < 1e-9:
            break
    t = model.plan(kmat, u, v)
    np.testing.assert_allclose(t.sum(axis=1, keepdims=True), a, rtol=1e-4)
    np.testing.assert_allclose(t.sum(axis=0, keepdims=True).T, b, rtol=1e-4)


def test_ot_objective_matches_ref():
    kmat, cost, a, b = _problem(16, seed=2)
    u = a  # arbitrary positive scalings
    v = b
    got = model.ot_objective(kmat, cost, u, v, jnp.float32(0.1))
    want = ref.ot_objective_ref(kmat, cost, u.ravel(), v.ravel(), 0.1)
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_uot_objective_matches_ref():
    kmat, cost, a, b = _problem(16, seed=3)
    u = 1.3 * a
    v = 0.7 * b
    got = model.uot_objective(
        kmat, cost, a, b, u, v, jnp.float32(1.0), jnp.float32(0.1)
    )
    want = ref.uot_objective_ref(
        kmat, cost, a.ravel(), b.ravel(), u.ravel(), v.ravel(), 1.0, 0.1
    )
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_uot_degenerates_to_ot_as_lambda_grows():
    """rho -> 1 as lam -> inf (Alg. 2 -> Alg. 1), Section 2.2."""
    kmat, _, a, b = _problem(32, seed=4)
    u0 = jnp.ones_like(a)
    v0 = jnp.ones_like(b)
    rho = 1e6 / (1e6 + 0.1)
    u1, v1, _ = model.sinkhorn_block(kmat, a, b, u0, v0, jnp.float32(rho))
    u2, v2, _ = model.sinkhorn_block(kmat, a, b, u0, v0, jnp.float32(1.0))
    np.testing.assert_allclose(u1, u2, rtol=1e-3)
    np.testing.assert_allclose(v1, v2, rtol=1e-3)


def test_kernel_from_cost():
    _, cost, _, _ = _problem(16, seed=6)
    kmat = model.kernel_from_cost(cost, jnp.float32(0.5))
    np.testing.assert_allclose(kmat, jnp.exp(-cost / 0.5), rtol=1e-6)


def test_specs_cover_all_entries():
    specs = model.specs_for(64)
    assert set(specs) == set(model.ENTRIES)
    for name, fn in model.ENTRIES.items():
        # Abstract evaluation must succeed for every entry at menu sizes.
        jax.eval_shape(fn, *specs[name])


@pytest.mark.parametrize("n", [64, 256])
def test_lowering_produces_hlo_text(n):
    from compile import aot

    text = aot.lower_entry("ot_objective", n)
    assert "HloModule" in text
    assert f"f32[{n},{n}]" in text
