//! Clean twin of `unordered_bad.rs`: the iteration is collected and
//! sorted before anything order-sensitive happens, and the pragma says
//! so — an honored (non-stale) pragma with a reason.

use std::collections::HashMap;

/// Assigns ids in sorted-key order regardless of hasher state.
pub fn assign_ids(groups: HashMap<u64, Vec<u32>>) -> Vec<(u64, usize)> {
    // lint: allow(unordered-iter, "collected and sorted by key before ids are assigned")
    let mut pairs: Vec<(u64, usize)> = groups.iter().map(|(k, v)| (*k, v.len())).collect();
    pairs.sort_by_key(|(k, _)| *k);
    pairs
}
