//! Scaling-loop backend selection: one switch for every scaling-loop
//! engine pair, across ALL formulations —
//!
//! | backend | dense OT | dense UOT | sparse OT/UOT | barycenter (dense / sketch) |
//! |---|---|---|---|---|
//! | `Multiplicative` | `ot::sinkhorn` | `ot::uot` | `solvers::sparse_loop` | `ot::barycenter` |
//! | `LogDomain` | `ot::log_sinkhorn` | `ot::log_sinkhorn` | `solvers::log_sparse` | `ot::log_barycenter` |
//!
//! `Auto` (the default) picks multiplicative above an ε threshold and
//! the stabilized log-domain engine below it, and ESCALATES a
//! multiplicative solve to the log engine when it fails numerically.
//! The collapse signals are shared and formulation-aware: an explicit
//! [`Error::Numerical`] (diverged scalings, non-finite objective), a
//! sketch whose stored kernel values materially underflowed (fully, or
//! > 1% of entries on a log-built sketch — the multiplicative loop would
//! silently iterate a biased sub-sketch), a scaling loop that
//! "converged" to the degenerate all-zero plan, or an IBP run whose
//! histogram carries numerically no mass (the barycenter shape of the
//! same collapse — without it a small-ε multiplicative IBP silently
//! returns a zero `q` instead of failing).
//!
//! The default threshold is calibrated to costs normalized to
//! `c₀ = max C = 1` (the standard preprocessing in
//! `ot::cost::normalize_cost`): `exp(−c₀/ε)` hits f64's
//! smallest positive normal at ε ≈ c₀/708 ≈ 1.4×10⁻³, so
//! [`DEFAULT_LOG_EPS_THRESHOLD`] = 2×10⁻³ switches just above the
//! cliff. Escalation-on-failure covers un-normalized costs, where the
//! cliff sits at a different ε.

use super::{log_sparse, sparse_loop};
use crate::error::{Error, Result};
use crate::linalg::Mat;
use crate::ot::barycenter::{ibp_barycenter_with, BarycenterSolution};
use crate::ot::cost::gibbs_kernel;
use crate::ot::log_barycenter::{log_ibp_barycenter, log_ibp_barycenter_with};
use crate::ot::log_sinkhorn::{log_sinkhorn_ot, log_sinkhorn_uot};
use crate::ot::sinkhorn::{sinkhorn_ot, SinkhornParams};
use crate::ot::uot::{sinkhorn_uot, uot_rho};
use crate::ot::SinkhornSolution;
use crate::sparse::CsrMatrix;

/// ε below which `Auto` goes straight to the log-domain engine (for
/// costs normalized to c₀ = 1; see the module docs).
pub const DEFAULT_LOG_EPS_THRESHOLD: f64 = 2e-3;

/// Which iteration engine runs the scaling loop.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ScalingBackend {
    /// Classic multiplicative `u/v` updates — fastest, but underflows
    /// for small ε.
    Multiplicative,
    /// Log-domain stabilized potentials — robust at any ε, roughly one
    /// `exp` per stored entry per iteration instead of one multiply.
    LogDomain,
    /// Multiplicative above `eps_threshold`, log-domain below it or on
    /// numerical failure of the multiplicative loop.
    Auto {
        /// ε below which the log engine is picked up front.
        eps_threshold: f64,
    },
}

impl Default for ScalingBackend {
    fn default() -> Self {
        ScalingBackend::Auto { eps_threshold: DEFAULT_LOG_EPS_THRESHOLD }
    }
}

/// The engine that actually produced a solution.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// The plain multiplicative scaling loop.
    Multiplicative,
    /// The log-sum-exp stabilized engine.
    LogDomain,
}

/// The multiplicative loop cannot work when stored kernel values
/// underflowed to 0. Fully underflowed sketches would silently
/// "converge" to the all-zero plan; on a log-built sketch even a
/// partial underflow means the loop iterates a biased sub-sketch
/// (underflowed entries carry a finite log-kernel but are invisible to
/// linear arithmetic), so escalate once that bias is material (> 1% of
/// stored entries). One O(nnz) pass, paid only under the `Auto` policy.
///
/// Formulation-aware: `mass` is whichever known marginal drives the
/// scaling loop — `a` for OT/UOT rows, `b_k` for the k-th IBP kernel
/// (the barycenter's own marginal is the unknown `q`). A sketch paired
/// with an all-zero marginal is an empty problem, not a hopeless one.
fn multiplicative_hopeless(sketch: &CsrMatrix, mass: &[f64]) -> bool {
    if sketch.nnz() == 0 || !mass.iter().any(|&x| x > 0.0) {
        return false;
    }
    let underflowed = sketch.iter().filter(|&(_, _, k, _)| k == 0.0).count();
    if underflowed == sketch.nnz() {
        return true;
    }
    sketch.has_log_kernel() && underflowed * 100 > sketch.nnz()
}

/// Dense shape of the same signal: a materialized Gibbs kernel whose
/// every entry underflowed. The multiplicative dense loops either
/// diverge (OT/UOT, caught via [`Error::Numerical`]) or — worse — the
/// guarded IBP update "converges" onto a zero histogram, so `Auto` goes
/// straight to the log engine instead of running them.
fn dense_kernel_hopeless(kernel: &Mat) -> bool {
    kernel.as_slice().iter().all(|&k| k == 0.0)
}

/// Partial-underflow collapse: the loop ran but every row scaling hit
/// the `sketch_div` zero branch — the plan is empty while the problem
/// is not. Treated as a failure worth escalating.
fn degenerate_all_zero(sol: &SinkhornSolution, sketch: &CsrMatrix, a: &[f64]) -> bool {
    sketch.nnz() > 0 && a.iter().any(|&x| x > 0.0) && sol.u.iter().all(|&x| x == 0.0)
}

/// Barycenter shape of the degenerate collapse: the IBP loop returned,
/// but the histogram carries numerically no mass (or non-finite
/// entries). A healthy IBP fixed point has `Σq = Σb_k = 1`; an
/// underflowed multiplicative run lands near `exp(Σ_k w_k ln 1e-300)`
/// per component instead of failing, so anything below 1e-100 total is
/// a collapse worth escalating, never a solution.
fn degenerate_barycenter(q: &[f64]) -> bool {
    !q.iter().all(|x| x.is_finite()) || q.iter().sum::<f64>() < 1e-100
}

fn mult_sparse_ot(
    sketch: &CsrMatrix,
    a: &[f64],
    b: &[f64],
    eps: f64,
    params: &SinkhornParams,
) -> Result<SinkhornSolution> {
    let (u, v, iterations, displacement, converged) =
        sparse_loop::sparse_scalings(sketch, a, b, 1.0, params)?;
    let objective = sparse_loop::sparse_ot_objective(sketch, &u, &v, eps);
    sparse_loop::solution(u, v, objective, iterations, displacement, converged)
}

fn mult_sparse_uot(
    sketch: &CsrMatrix,
    a: &[f64],
    b: &[f64],
    lambda: f64,
    eps: f64,
    params: &SinkhornParams,
) -> Result<SinkhornSolution> {
    let rho = uot_rho(lambda, eps);
    let (u, v, iterations, displacement, converged) =
        sparse_loop::sparse_scalings(sketch, a, b, rho, params)?;
    let objective = sparse_loop::sparse_uot_objective(sketch, a, b, &u, &v, lambda, eps);
    sparse_loop::solution(u, v, objective, iterations, displacement, converged)
}

fn log_sparse_ot_solve(
    sketch: &CsrMatrix,
    a: &[f64],
    b: &[f64],
    eps: f64,
    params: &SinkhornParams,
) -> Result<SinkhornSolution> {
    let (phi, psi, iterations, displacement, converged) =
        log_sparse::log_sparse_scalings(sketch, a, b, 1.0, eps, params)?;
    let objective = log_sparse::log_sparse_ot_objective(sketch, &phi, &psi, eps);
    log_sparse::solution(phi, psi, objective, iterations, displacement, converged)
}

fn log_sparse_uot_solve(
    sketch: &CsrMatrix,
    a: &[f64],
    b: &[f64],
    lambda: f64,
    eps: f64,
    params: &SinkhornParams,
) -> Result<SinkhornSolution> {
    let rho = uot_rho(lambda, eps);
    let (phi, psi, iterations, displacement, converged) =
        log_sparse::log_sparse_scalings(sketch, a, b, rho, eps, params)?;
    let objective = log_sparse::log_sparse_uot_objective(sketch, a, b, &phi, &psi, lambda, eps);
    log_sparse::solution(phi, psi, objective, iterations, displacement, converged)
}

impl ScalingBackend {
    /// The default `Auto` policy.
    pub fn auto() -> Self {
        Self::default()
    }

    /// Whether this policy may fall back to the log engine after a
    /// multiplicative failure.
    fn escalates(&self) -> bool {
        matches!(self, ScalingBackend::Auto { .. })
    }

    /// Which concrete engine runs at this ε (before any
    /// failure-triggered escalation).
    pub fn kind_for(&self, eps: f64) -> BackendKind {
        match *self {
            ScalingBackend::Multiplicative => BackendKind::Multiplicative,
            ScalingBackend::LogDomain => BackendKind::LogDomain,
            ScalingBackend::Auto { eps_threshold } => {
                if eps < eps_threshold {
                    BackendKind::LogDomain
                } else {
                    BackendKind::Multiplicative
                }
            }
        }
    }

    /// Sparse entropic-OT solve over a sketch (scalings + objective),
    /// escalating per the policy. Returns the solution and the engine
    /// that produced it.
    pub fn sparse_ot(
        &self,
        sketch: &CsrMatrix,
        a: &[f64],
        b: &[f64],
        eps: f64,
        params: &SinkhornParams,
    ) -> Result<(SinkhornSolution, BackendKind)> {
        let mut kind = self.kind_for(eps);
        if kind == BackendKind::Multiplicative
            && self.escalates()
            && multiplicative_hopeless(sketch, a)
        {
            kind = BackendKind::LogDomain;
        }
        if kind == BackendKind::Multiplicative {
            match mult_sparse_ot(sketch, a, b, eps, params) {
                Ok(sol) if !(self.escalates() && degenerate_all_zero(&sol, sketch, a)) => {
                    return Ok((sol, BackendKind::Multiplicative));
                }
                Ok(_) => {} // degenerate collapse -> escalate
                Err(Error::Numerical(_)) if self.escalates() => {} // diverged -> escalate
                Err(e) => return Err(e),
            }
        }
        log_sparse_ot_solve(sketch, a, b, eps, params).map(|s| (s, BackendKind::LogDomain))
    }

    /// Sparse entropic-UOT solve over a sketch, escalating per the
    /// policy.
    #[allow(clippy::too_many_arguments)]
    pub fn sparse_uot(
        &self,
        sketch: &CsrMatrix,
        a: &[f64],
        b: &[f64],
        lambda: f64,
        eps: f64,
        params: &SinkhornParams,
    ) -> Result<(SinkhornSolution, BackendKind)> {
        let mut kind = self.kind_for(eps);
        if kind == BackendKind::Multiplicative
            && self.escalates()
            && multiplicative_hopeless(sketch, a)
        {
            kind = BackendKind::LogDomain;
        }
        if kind == BackendKind::Multiplicative {
            match mult_sparse_uot(sketch, a, b, lambda, eps, params) {
                Ok(sol) if !(self.escalates() && degenerate_all_zero(&sol, sketch, a)) => {
                    return Ok((sol, BackendKind::Multiplicative));
                }
                Ok(_) => {}
                Err(Error::Numerical(_)) if self.escalates() => {}
                Err(e) => return Err(e),
            }
        }
        log_sparse_uot_solve(sketch, a, b, lambda, eps, params)
            .map(|s| (s, BackendKind::LogDomain))
    }

    /// Dense entropic-OT solve from a cost matrix: the multiplicative
    /// path materializes the Gibbs kernel, the log path works on the
    /// cost directly. This is the dense side of the unification — use it
    /// wherever an "exact" reference must stay stable at small ε.
    pub fn dense_ot(
        &self,
        cost: &Mat,
        a: &[f64],
        b: &[f64],
        eps: f64,
        params: &SinkhornParams,
    ) -> Result<(SinkhornSolution, BackendKind)> {
        match self.kind_for(eps) {
            BackendKind::Multiplicative => {
                let kernel = gibbs_kernel(cost, eps);
                match sinkhorn_ot(&kernel, cost, a, b, eps, params) {
                    Ok(sol) => Ok((sol, BackendKind::Multiplicative)),
                    Err(Error::Numerical(_)) if self.escalates() => {
                        log_sinkhorn_ot(cost, a, b, eps, params)
                            .map(|s| (s, BackendKind::LogDomain))
                    }
                    Err(e) => Err(e),
                }
            }
            BackendKind::LogDomain => {
                log_sinkhorn_ot(cost, a, b, eps, params).map(|s| (s, BackendKind::LogDomain))
            }
        }
    }

    /// Dense entropic-UOT solve from a cost matrix — the unbalanced twin
    /// of [`ScalingBackend::dense_ot`]. The multiplicative path
    /// materializes the Gibbs kernel and runs Algorithm 2; the log path
    /// iterates `ρ`-scaled potentials on the cost directly
    /// ([`log_sinkhorn_uot`]), so a `LogDomain` override (or an `Auto`
    /// escalation) keeps dense unbalanced problems solvable at any ε.
    pub fn dense_uot(
        &self,
        cost: &Mat,
        a: &[f64],
        b: &[f64],
        lambda: f64,
        eps: f64,
        params: &SinkhornParams,
    ) -> Result<(SinkhornSolution, BackendKind)> {
        match self.kind_for(eps) {
            BackendKind::Multiplicative => {
                let kernel = gibbs_kernel(cost, eps);
                if self.escalates() && dense_kernel_hopeless(&kernel) {
                    return log_sinkhorn_uot(cost, a, b, lambda, eps, params)
                        .map(|s| (s, BackendKind::LogDomain));
                }
                match sinkhorn_uot(&kernel, cost, a, b, lambda, eps, params) {
                    Ok(sol) => Ok((sol, BackendKind::Multiplicative)),
                    Err(Error::Numerical(_)) if self.escalates() => {
                        log_sinkhorn_uot(cost, a, b, lambda, eps, params)
                            .map(|s| (s, BackendKind::LogDomain))
                    }
                    Err(e) => Err(e),
                }
            }
            BackendKind::LogDomain => log_sinkhorn_uot(cost, a, b, lambda, eps, params)
                .map(|s| (s, BackendKind::LogDomain)),
        }
    }

    /// Dense IBP barycenter solve from the shared-support cost matrix.
    /// The multiplicative path materializes one Gibbs kernel per input
    /// measure and runs Algorithm 5; the log path runs the stabilized
    /// log-IBP ([`log_ibp_barycenter`]). Escalation watches the
    /// barycenter-shaped collapse ([`degenerate_barycenter`]) — the
    /// guarded multiplicative update does NOT error on an underflowed
    /// kernel, it silently converges onto a zero histogram.
    pub fn dense_ibp(
        &self,
        cost: &Mat,
        bs: &[Vec<f64>],
        weights: &[f64],
        eps: f64,
        params: &SinkhornParams,
    ) -> Result<(BarycenterSolution, BackendKind)> {
        match self.kind_for(eps) {
            BackendKind::Multiplicative => {
                let kernel = gibbs_kernel(cost, eps);
                if self.escalates() && dense_kernel_hopeless(&kernel) {
                    return log_ibp_barycenter(cost, bs, weights, eps, params)
                        .map(|s| (s, BackendKind::LogDomain));
                }
                // One shared kernel for every input measure (same
                // support) — pass references instead of m dense clones.
                let kernels: Vec<&Mat> = vec![&kernel; bs.len()];
                match ibp_barycenter_with(&kernels, bs, weights, params) {
                    Ok(sol) if !(self.escalates() && degenerate_barycenter(&sol.q)) => {
                        Ok((sol, BackendKind::Multiplicative))
                    }
                    Ok(_) => log_ibp_barycenter(cost, bs, weights, eps, params)
                        .map(|s| (s, BackendKind::LogDomain)),
                    Err(Error::Numerical(_)) if self.escalates() => {
                        log_ibp_barycenter(cost, bs, weights, eps, params)
                            .map(|s| (s, BackendKind::LogDomain))
                    }
                    Err(e) => Err(e),
                }
            }
            BackendKind::LogDomain => log_ibp_barycenter(cost, bs, weights, eps, params)
                .map(|s| (s, BackendKind::LogDomain)),
        }
    }

    /// Sketched IBP barycenter solve over per-measure sketches (the
    /// Spar-IBP scaling stage). Sketches must carry exact log-kernel
    /// values (the `_logk` samplers) for the log engine to add anything
    /// over the multiplicative loop. `eps` only steers the `Auto`
    /// threshold — the kernels' ε is baked into the sketches.
    pub fn sparse_ibp(
        &self,
        sketches: &[CsrMatrix],
        bs: &[Vec<f64>],
        weights: &[f64],
        eps: f64,
        params: &SinkhornParams,
    ) -> Result<(BarycenterSolution, BackendKind)> {
        let mut kind = self.kind_for(eps);
        if kind == BackendKind::Multiplicative
            && self.escalates()
            && sketches.iter().zip(bs).any(|(sk, b)| multiplicative_hopeless(sk, b))
        {
            kind = BackendKind::LogDomain;
        }
        if kind == BackendKind::Multiplicative {
            match ibp_barycenter_with(sketches, bs, weights, params) {
                Ok(sol) if !(self.escalates() && degenerate_barycenter(&sol.q)) => {
                    return Ok((sol, BackendKind::Multiplicative));
                }
                Ok(_) => {} // zero-mass collapse -> escalate
                Err(Error::Numerical(_)) if self.escalates() => {} // diverged -> escalate
                Err(e) => return Err(e),
            }
        }
        log_ibp_barycenter_with(sketches, bs, weights, params)
            .map(|s| (s, BackendKind::LogDomain))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ot::cost::sq_euclidean_cost;
    use crate::sparse::csr::CsrMatrix as Csr;

    fn toy(n: usize) -> (Mat, Vec<f64>, Vec<f64>) {
        let pts: Vec<Vec<f64>> = (0..n)
            .map(|i| vec![(i as f64 * 0.618).fract(), (i as f64 * 0.383).fract()])
            .collect();
        let cost = sq_euclidean_cost(&pts, &pts);
        let a = vec![1.0 / n as f64; n];
        let b: Vec<f64> = (0..n).map(|i| 1.0 + (i % 2) as f64).collect();
        let sb: f64 = b.iter().sum();
        (cost, a, b.iter().map(|x| x / sb).collect())
    }

    fn full_csr_logk(cost: &Mat, eps: f64) -> Csr {
        let rows = (0..cost.rows())
            .map(|i| {
                (0..cost.cols())
                    .map(|j| {
                        let c = cost.get(i, j);
                        let lk = -c / eps;
                        (j as u32, lk.exp(), lk, c)
                    })
                    .collect()
            })
            .collect();
        Csr::from_rows_logk(cost.rows(), cost.cols(), rows)
    }

    #[test]
    fn auto_picks_engine_by_eps() {
        let auto = ScalingBackend::default();
        assert_eq!(auto.kind_for(0.1), BackendKind::Multiplicative);
        assert_eq!(auto.kind_for(1e-4), BackendKind::LogDomain);
        assert_eq!(
            ScalingBackend::Multiplicative.kind_for(1e-9),
            BackendKind::Multiplicative
        );
        assert_eq!(ScalingBackend::LogDomain.kind_for(1.0), BackendKind::LogDomain);
    }

    #[test]
    fn backends_agree_at_moderate_eps() {
        let (cost, a, b) = toy(20);
        let eps = 0.1;
        let sk = full_csr_logk(&cost, eps);
        let params = SinkhornParams { delta: 0.0, max_iters: 300, strict: false };
        let (mult, km) = ScalingBackend::Multiplicative
            .sparse_ot(&sk, &a, &b, eps, &params)
            .unwrap();
        let (logd, kl) = ScalingBackend::LogDomain.sparse_ot(&sk, &a, &b, eps, &params).unwrap();
        let (auto, ka) = ScalingBackend::default().sparse_ot(&sk, &a, &b, eps, &params).unwrap();
        assert_eq!(km, BackendKind::Multiplicative);
        assert_eq!(kl, BackendKind::LogDomain);
        assert_eq!(ka, BackendKind::Multiplicative);
        assert!((mult.objective - logd.objective).abs() < 1e-8);
        assert!((mult.objective - auto.objective).abs() < 1e-12);
    }

    #[test]
    fn auto_escalates_on_fully_underflowed_sketch() {
        // ε tiny but ABOVE the auto threshold would be the dangerous
        // case; force it by using a zero threshold so Auto starts
        // multiplicative, then sees the hopeless all-zero kernel. The
        // cost is shifted by 1 so even the diagonal underflows.
        let (cost, a, b) = toy(12);
        let cost = cost.map(|c| c + 1.0);
        let eps = 1e-6;
        let sk = full_csr_logk(&cost, eps);
        assert_eq!(sk.kernel_frob_norm(), 0.0, "expected full underflow");
        let params = SinkhornParams { delta: 1e-8, max_iters: 300, strict: false };
        let forced_mult = ScalingBackend::Auto { eps_threshold: 0.0 };
        let (sol, kind) = forced_mult.sparse_ot(&sk, &a, &b, eps, &params).unwrap();
        assert_eq!(kind, BackendKind::LogDomain, "should have escalated");
        assert!(sol.objective.is_finite());
        // The pure multiplicative backend on the same sketch collapses
        // to the empty plan (objective 0) or errors — never a healthy
        // positive objective.
        match ScalingBackend::Multiplicative.sparse_ot(&sk, &a, &b, eps, &params) {
            Ok(s) => assert!(s.objective <= 1e-12, "unexpectedly healthy: {}", s.objective),
            Err(Error::Numerical(_)) => {}
            Err(e) => panic!("unexpected error {e}"),
        }
    }

    #[test]
    fn dense_ot_unifies_both_loops() {
        let (cost, a, b) = toy(16);
        // Normalize so the documented threshold calibration applies.
        let cost = crate::ot::cost::normalize_cost(&cost);
        let params = SinkhornParams { delta: 1e-9, max_iters: 4000, strict: false };
        // Moderate ε: auto runs multiplicative.
        let (sol_m, kind_m) =
            ScalingBackend::default().dense_ot(&cost, &a, &b, 0.1, &params).unwrap();
        assert_eq!(kind_m, BackendKind::Multiplicative);
        // Small ε: auto runs log-domain and stays finite.
        let (sol_l, kind_l) =
            ScalingBackend::default().dense_ot(&cost, &a, &b, 1e-4, &params).unwrap();
        assert_eq!(kind_l, BackendKind::LogDomain);
        assert!(sol_m.objective.is_finite());
        assert!(sol_l.objective.is_finite());
        // Both agree with the explicit log solver at moderate ε.
        let reference = log_sinkhorn_ot(&cost, &a, &b, 0.1, &params).unwrap();
        let rel = (sol_m.objective - reference.objective).abs() / reference.objective.abs();
        assert!(rel < 1e-4, "mult {} vs log {}", sol_m.objective, reference.objective);
    }

    fn bary_fixture(n: usize) -> (Mat, Vec<Vec<f64>>, Vec<f64>) {
        let pts: Vec<Vec<f64>> = (0..n).map(|i| vec![i as f64 / (n - 1) as f64]).collect();
        let cost = sq_euclidean_cost(&pts, &pts);
        let hist = |mu: f64| -> Vec<f64> {
            let w: Vec<f64> =
                pts.iter().map(|p| (-(p[0] - mu).powi(2) / 0.01).exp() + 1e-4).collect();
            let s: f64 = w.iter().sum();
            w.iter().map(|x| x / s).collect()
        };
        (cost, vec![hist(0.25), hist(0.75)], vec![0.5, 0.5])
    }

    #[test]
    fn dense_uot_unifies_both_loops() {
        let (cost, a, b) = toy(16);
        let cost = crate::ot::cost::normalize_cost(&cost);
        let a: Vec<f64> = a.iter().map(|x| x * 2.0).collect();
        let params = SinkhornParams { delta: 1e-10, max_iters: 5000, strict: false };
        let lambda = 1.0;
        // Moderate ε: auto runs multiplicative.
        let (sol_m, kind_m) =
            ScalingBackend::default().dense_uot(&cost, &a, &b, lambda, 0.1, &params).unwrap();
        assert_eq!(kind_m, BackendKind::Multiplicative);
        // Small ε: auto runs log-domain and stays finite.
        let (sol_l, kind_l) =
            ScalingBackend::default().dense_uot(&cost, &a, &b, lambda, 1e-4, &params).unwrap();
        assert_eq!(kind_l, BackendKind::LogDomain);
        assert!(sol_m.objective.is_finite() && sol_l.objective.is_finite());
        // Forced log agrees with multiplicative at moderate ε.
        let (logd, kl) = ScalingBackend::LogDomain
            .dense_uot(&cost, &a, &b, lambda, 0.1, &params)
            .unwrap();
        assert_eq!(kl, BackendKind::LogDomain);
        let rel = (sol_m.objective - logd.objective).abs() / logd.objective.abs();
        assert!(rel < 1e-6, "mult {} vs log {}", sol_m.objective, logd.objective);
    }

    #[test]
    fn dense_ibp_auto_switches_and_backends_agree() {
        let (cost, bs, w) = bary_fixture(32);
        let params = SinkhornParams { delta: 1e-11, max_iters: 20_000, strict: false };
        let eps = 0.01;
        let (mult, km) = ScalingBackend::Multiplicative
            .dense_ibp(&cost, &bs, &w, eps, &params)
            .unwrap();
        let (logd, kl) =
            ScalingBackend::LogDomain.dense_ibp(&cost, &bs, &w, eps, &params).unwrap();
        let (auto, ka) =
            ScalingBackend::default().dense_ibp(&cost, &bs, &w, eps, &params).unwrap();
        assert_eq!(km, BackendKind::Multiplicative);
        assert_eq!(kl, BackendKind::LogDomain);
        assert_eq!(ka, BackendKind::Multiplicative);
        let mass: f64 = mult.q.iter().sum();
        let sup = mult
            .q
            .iter()
            .zip(&logd.q)
            .map(|(x, y)| (x / mass - y).abs())
            .fold(0.0f64, f64::max);
        assert!(sup < 1e-8, "normalized sup gap {sup}");
        assert_eq!(auto.q.len(), mult.q.len());
        // Sub-threshold ε: auto goes to the log engine and returns a
        // probability vector where the multiplicative loop collapses.
        let (small, ks) =
            ScalingBackend::default().dense_ibp(&cost, &bs, &w, 1e-5, &params).unwrap();
        assert_eq!(ks, BackendKind::LogDomain);
        let mass: f64 = small.q.iter().sum();
        assert!((mass - 1.0).abs() < 1e-9, "mass {mass}");
    }

    #[test]
    fn sparse_ibp_escalates_on_underflowed_sketch() {
        // Shift the cost by 1 so even the diagonal underflows at tiny ε,
        // and force Auto to START multiplicative with a zero threshold:
        // the hopeless-sketch check must reroute to the log engine
        // instead of letting IBP "converge" onto a zero histogram.
        let (cost, bs, w) = bary_fixture(16);
        let cost = cost.map(|c| c + 1.0);
        let eps = 1e-6;
        let sk = full_csr_logk(&cost, eps);
        assert_eq!(sk.kernel_frob_norm(), 0.0, "expected full underflow");
        let sketches = vec![sk.clone(), sk];
        let params = SinkhornParams { delta: 1e-8, max_iters: 500, strict: false };
        let forced_mult = ScalingBackend::Auto { eps_threshold: 0.0 };
        let (sol, kind) = forced_mult.sparse_ibp(&sketches, &bs, &w, eps, &params).unwrap();
        assert_eq!(kind, BackendKind::LogDomain, "should have escalated");
        let mass: f64 = sol.q.iter().sum();
        assert!((mass - 1.0).abs() < 1e-9, "mass {mass}");
        // The pinned multiplicative backend on the same sketches returns
        // the collapsed histogram (or errors) — never a healthy q.
        match ScalingBackend::Multiplicative.sparse_ibp(&sketches, &bs, &w, eps, &params) {
            Ok((s, k)) => {
                assert_eq!(k, BackendKind::Multiplicative);
                assert!(
                    s.q.iter().sum::<f64>() < 1e-100,
                    "unexpectedly healthy mass {}",
                    s.q.iter().sum::<f64>()
                );
            }
            Err(Error::Numerical(_)) => {}
            Err(e) => panic!("unexpected error {e}"),
        }
    }

    #[test]
    fn sparse_ibp_runs_multiplicative_at_moderate_eps() {
        let (cost, bs, w) = bary_fixture(24);
        let eps = 0.01;
        let sk = full_csr_logk(&cost, eps);
        let sketches = vec![sk.clone(), sk];
        let params = SinkhornParams { delta: 1e-9, max_iters: 5000, strict: false };
        let (sol, kind) =
            ScalingBackend::default().sparse_ibp(&sketches, &bs, &w, eps, &params).unwrap();
        assert_eq!(kind, BackendKind::Multiplicative);
        let mass: f64 = sol.q.iter().sum();
        assert!((mass - 1.0).abs() < 1e-6, "mass {mass}");
    }

    #[test]
    fn uot_backends_agree_at_moderate_eps() {
        let (cost, a, b) = toy(14);
        let eps = 0.1;
        let lambda = 1.0;
        let sk = full_csr_logk(&cost, eps);
        let params = SinkhornParams { delta: 0.0, max_iters: 400, strict: false };
        let (mult, _) = ScalingBackend::Multiplicative
            .sparse_uot(&sk, &a, &b, lambda, eps, &params)
            .unwrap();
        let (logd, _) =
            ScalingBackend::LogDomain.sparse_uot(&sk, &a, &b, lambda, eps, &params).unwrap();
        assert!((mult.objective - logd.objective).abs() < 1e-8, "{} vs {}", mult.objective, logd.objective);
    }
}
