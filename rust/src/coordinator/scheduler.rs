//! Routing + admission: the batcher thread and the fingerprint-affine
//! shard router.
//!
//! The batcher collects queued jobs until `max_batch` or
//! `batch_window`, groups them by (method, size bucket), and flushes
//! one [`Batch`] per group — groups sorted by key before ids are
//! assigned, so an identical submission sequence always yields
//! identical batch ids (a `HashMap` iteration here used to make ids
//! vary run to run).
//!
//! Routing is FINGERPRINT-AFFINE: each batch carries the content
//! address ([`Fingerprint`]) of its jobs' cost geometry, and every
//! batch sharing a fingerprint is routed to the same shard
//! (`routing_key % shards`). Artifact-cache hits therefore stay
//! shard-local — no cross-core traffic on the cached kernel, and
//! single-flight contention never crosses shards — while batches
//! without a shareable fingerprint (oversized grids that keep the
//! oracle path) round-robin across shards. The `sketch_budget`
//! contract makes this safe: placement can never change a sketch, so
//! routing is purely a locality decision (pinned bitwise by
//! `cache_parity`).

use std::collections::HashMap;
use std::sync::atomic::Ordering;
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::jobs::{BarycenterJob, BarycenterResult, DistanceJob, DistanceResult, Method};
use super::service::{CoordinatorConfig, Shared};
use super::shard::Shard;
use crate::engine::Fingerprint;
use crate::solvers::backend::ScalingBackend;

/// One queued unit of work. Distance (pairwise WFR) and barycenter jobs
/// share the queue, the batcher, and the worker pool — they differ only
/// in how the worker expresses them as an
/// [`OtProblem`](crate::api::OtProblem).
pub(crate) enum QueuedJob {
    /// A pairwise WFR-distance job plus its response channel.
    Distance {
        /// The job as submitted.
        job: DistanceJob,
        /// Submission time (end-to-end latency baseline).
        enqueued: Instant,
        /// Where the worker sends the result.
        respond: Sender<DistanceResult>,
    },
    /// A fixed-support barycenter job plus its response channel.
    Barycenter {
        /// The job as submitted.
        job: BarycenterJob,
        /// Submission time (end-to-end latency baseline).
        enqueued: Instant,
        /// Where the worker sends the result.
        respond: Sender<BarycenterResult>,
    },
}

impl QueuedJob {
    pub(crate) fn method(&self) -> Method {
        match self {
            QueuedJob::Distance { job, .. } => job.method,
            QueuedJob::Barycenter { job, .. } => job.method,
        }
    }

    /// Problem size driving the batching bucket.
    fn size(&self) -> usize {
        match self {
            QueuedJob::Distance { job, .. } => job.source.len().max(job.target.len()),
            QueuedJob::Barycenter { job, .. } => job.support_len(),
        }
    }

    /// Whether this job pinned the log-domain engine itself (such jobs
    /// are not escalations when they report `BackendKind::LogDomain`).
    pub(crate) fn forces_log_domain(&self) -> bool {
        let (method, spec) = match self {
            QueuedJob::Distance { job, .. } => (job.method, &job.spec),
            QueuedJob::Barycenter { job, .. } => (job.method, &job.spec),
        };
        method == Method::SparSinkLog
            || matches!(spec.backend, Some(ScalingBackend::LogDomain))
    }

    /// The content address of this job's cost geometry — delegates to
    /// the job types' public
    /// [`routing_fingerprint`](DistanceJob::routing_fingerprint), the
    /// ONE computation shared by this router, the worker's cache
    /// lookup, and the multi-process balancer in [`crate::net`], so
    /// routing and caching can never disagree. `None` = oversized: the
    /// worker keeps the cold oracle path and the router falls back to
    /// round-robin.
    pub(crate) fn fingerprint(&self) -> Option<Fingerprint> {
        match self {
            QueuedJob::Distance { job, .. } => job.routing_fingerprint(),
            QueuedJob::Barycenter { job, .. } => job.routing_fingerprint(),
        }
    }
}

/// A flushed group of jobs. The id is assigned by the batcher at flush
/// time and travels WITH the batch — workers must not re-read the
/// global counter, which races when several batches are in flight. The
/// fingerprint is the group's routing affinity (the first job's, when
/// shareable).
pub(crate) struct Batch {
    pub(crate) id: u64,
    pub(crate) fingerprint: Option<Fingerprint>,
    pub(crate) jobs: Vec<QueuedJob>,
}

/// Size bucket: log2 of support size — jobs in a batch have comparable
/// cost, keeping batch latency predictable.
fn size_bucket(job: &QueuedJob) -> u32 {
    let n = job.size().max(1);
    usize::BITS - n.leading_zeros()
}

/// The shard router. Batches with a shareable fingerprint are placed by
/// `routing_key % shards` — a pure function of the content address, so
/// one fingerprint always lands on one shard; fingerprint-less batches
/// round-robin for balance.
struct Router {
    shards: Vec<Arc<Shard>>,
    round_robin: usize,
}

impl Router {
    fn route(&mut self, batch: Batch) {
        let slot = match &batch.fingerprint {
            Some(fp) => (fp.routing_key() % self.shards.len() as u64) as usize,
            None => {
                let slot = self.round_robin;
                self.round_robin = (self.round_robin + 1) % self.shards.len();
                slot
            }
        };
        self.shards[slot].push(batch);
    }
}

/// The batcher thread: collect jobs until `max_batch` or
/// `batch_window`, then flush groups through the router. Exits when the
/// submission channel closes (after routing everything still pending).
pub(crate) fn batcher_loop(
    rx: Receiver<QueuedJob>,
    cfg: CoordinatorConfig,
    shared: Arc<Shared>,
    shards: Vec<Arc<Shard>>,
) {
    let mut router = Router { shards, round_robin: 0 };
    let mut pending: Vec<QueuedJob> = Vec::new();
    let mut window_start: Option<Instant> = None;
    loop {
        let timeout = match window_start {
            Some(t0) => cfg
                .batch_window
                .checked_sub(t0.elapsed())
                .unwrap_or(Duration::ZERO),
            None => Duration::from_millis(50),
        };
        match rx.recv_timeout(timeout) {
            Ok(job) => {
                if pending.is_empty() {
                    window_start = Some(Instant::now());
                }
                pending.push(job);
                if pending.len() >= cfg.max_batch {
                    flush(&mut pending, &mut router, &shared);
                    window_start = None;
                }
            }
            Err(RecvTimeoutError::Timeout) => {
                if !pending.is_empty() {
                    flush(&mut pending, &mut router, &shared);
                    window_start = None;
                }
            }
            Err(RecvTimeoutError::Disconnected) => {
                if !pending.is_empty() {
                    flush(&mut pending, &mut router, &shared);
                }
                break;
            }
        }
    }
}

/// Group pending jobs by (method, size bucket), assign batch ids in
/// sorted-key order, and route each batch to its shard.
fn flush(pending: &mut Vec<QueuedJob>, router: &mut Router, shared: &Arc<Shared>) {
    let mut groups: HashMap<(usize, u32), Vec<QueuedJob>> = HashMap::new();
    for job in pending.drain(..) {
        groups
            .entry((job.method().index(), size_bucket(&job)))
            .or_default()
            .push(job);
    }
    // Sort groups by key before assigning ids: a `HashMap` iteration
    // made batch ids for an identical submission sequence vary run to
    // run (and across shard counts), breaking the determinism contract.
    // lint: allow(unordered-iter, "collected into a Vec and sorted by key before ids are assigned")
    let mut sorted_groups: Vec<_> = groups.into_iter().collect();
    sorted_groups.sort_by_key(|(key, _)| *key);
    for (_, jobs) in sorted_groups {
        // Assign the id HERE and carry it with the batch: workers
        // re-reading the counter would see whatever batch was flushed
        // most recently, reporting wrong/duplicate ids under
        // concurrency.
        let id = shared.batches.fetch_add(1, Ordering::Relaxed) + 1;
        let fingerprint = jobs.iter().find_map(QueuedJob::fingerprint);
        router.route(Batch { id, fingerprint, jobs });
    }
}
