//! Appendix Fig. 11 — Wasserstein barycenter approximation error versus
//! s: IBP (truth) vs Nys-IBP, Rand-IBP and Spar-IBP, over
//! ε ∈ {5e-2, 1e-2(≈5⁰·1e-2), 5e-3}·… (paper: {5, 1, 0.2}·1e-1-ish menu,
//! we use {5e-2, 1e-2, 5e-3}) and d ∈ {5, 10, 20}.
//!
//! All arms share ONE cost/kernel materialization per (ε, d) through
//! [`CostArtifacts`]: the exact IBP truth and the Rand/Nys ablations
//! read the cached Gibbs kernel, and the Spar-IBP replication sweep
//! dispatches through [`api::solve_batch`] on a
//! [`CostSource::Shared`](crate::api::CostSource) handle — the
//! per-(ε, pair) `sq_euclidean_cost` + `gibbs_kernel` rebuilds of the
//! cold harness are gone.

use std::sync::Arc;

use super::common::row;
use super::{ExperimentOutput, Profile};
use crate::api::{self, Method as ApiMethod, OtProblem, SolverSpec};
use crate::data::synthetic::barycenter_measures;
use crate::engine::{CostArtifacts, CostHandle, FormulationKey};
use crate::linalg::Mat;
use crate::metrics::{l1_distance, mean_sd, normalized_histogram, s0};
use crate::ot::barycenter::ibp_barycenter_with;
use crate::ot::cost::{normalize_cost, sq_euclidean_cost};
use crate::ot::sinkhorn::SinkhornParams;
use crate::rng::Rng;
use crate::sparse::poisson_sparsify_with;
use crate::util::json::Json;
use crate::util::table::{f, Table};

/// Rand-IBP: uniform-probability sparsification of the shared kernel,
/// one sketch per input measure.
fn rand_ibp(
    kernel: &Mat,
    n_measures: usize,
    bs: &[Vec<f64>],
    w: &[f64],
    s: f64,
    params: &SinkhornParams,
    rng: &mut Rng,
) -> crate::error::Result<Vec<f64>> {
    let n2 = (kernel.rows() * kernel.cols()) as f64;
    let mut sketches = Vec::new();
    for _ in 0..n_measures {
        let (sk, _) = poisson_sparsify_with(
            kernel.rows(),
            kernel.cols(),
            |i, j| kernel.get(i, j),
            |_, _| 0.0,
            |_, _| 1.0,
            n2,
            s,
            1.0,
            rng,
        )?;
        sketches.push(sk);
    }
    Ok(ibp_barycenter_with(&sketches, bs, w, params)?.q)
}

/// Nys-IBP: ONE low-rank factor of the shared kernel drives the IBP
/// loop for every input measure (the kernels are identical, so the
/// per-kernel factorizations of the cold harness were pure waste).
fn nys_ibp(
    kernel: &Mat,
    n_measures: usize,
    bs: &[Vec<f64>],
    w: &[f64],
    rank: usize,
    params: &SinkhornParams,
    rng: &mut Rng,
) -> crate::error::Result<Vec<f64>> {
    use crate::linalg::nystrom_factorize;
    use crate::ot::barycenter::KernelOp;

    struct NysOp(crate::linalg::NystromFactor, usize);
    impl KernelOp for NysOp {
        fn apply(&self, x: &[f64]) -> Vec<f64> {
            self.0.matvec(x).iter().map(|&v| v.max(0.0)).collect()
        }
        fn apply_t(&self, x: &[f64]) -> Vec<f64> {
            self.0.matvec_t(x).iter().map(|&v| v.max(0.0)).collect()
        }
        fn rows(&self) -> usize {
            self.1
        }
        fn cols(&self) -> usize {
            self.1
        }
    }
    let n = kernel.rows();
    let op = NysOp(nystrom_factorize(n, |i, j| kernel.get(i, j), rank, 1e-10, rng), n);
    let ops: Vec<&NysOp> = vec![&op; n_measures];
    Ok(ibp_barycenter_with(&ops, bs, w, params)?.q)
}

/// Appendix Figure 11: barycenter error vs budget s for Spar-IBP, on shared-cost artifacts.
pub fn run(profile: Profile) -> ExperimentOutput {
    let n = profile.pick(300, 1000);
    let reps = profile.reps(3, 100);
    let dims: &[usize] = profile.pick(&[5usize][..], &[5, 10, 20][..]);
    let epss = [5e-2, 1e-2, 5e-3];
    let s_mults = [5.0, 10.0, 15.0, 20.0];
    let params = SinkhornParams { delta: 1e-7, max_iters: 1000, strict: false };

    let mut table = Table::new(&["eps", "d", "method", "s/s0", "L1 err", "se"]);
    let mut rows = Vec::new();
    let mut rng = Rng::seed_from(0xF171);
    for &eps in &epss {
        for &d in dims {
            // Shared uniform support in (0,1)^d; cost + kernel built
            // exactly once and consumed by every arm below.
            let pts: Vec<Vec<f64>> =
                (0..n).map(|_| (0..d).map(|_| rng.uniform()).collect()).collect();
            let cost = Arc::new(normalize_cost(&sq_euclidean_cost(&pts, &pts)));
            let arts = CostArtifacts::from_dense(cost, eps, FormulationKey::Barycenter);
            let handle = CostHandle::new(arts.clone());
            let kernel: &Mat = &arts.kernel;
            let bs = barycenter_measures(n, &mut rng);
            let w = vec![1.0 / 3.0; 3];
            let kernel_refs: Vec<&Mat> = vec![kernel; 3];
            let Ok(exact) = ibp_barycenter_with(&kernel_refs, &bs, &w, &params) else {
                continue;
            };
            let truth = normalized_histogram(&exact.q);

            for &s_mult in &s_mults {
                let budget = s_mult * s0(n);
                // Spar-IBP replicates ride the batch API on the shared
                // handle (problem i is seeded spec.seed + i).
                let problems: Vec<OtProblem> = (0..reps)
                    .map(|_| {
                        OtProblem::barycenter(handle.clone(), bs.clone(), w.clone(), eps)
                    })
                    .collect();
                let spec = SolverSpec::new(ApiMethod::SparIbp)
                    .with_budget(s_mult)
                    .with_tolerance(params.delta)
                    .with_max_iters(params.max_iters)
                    .with_seed(rng.next_u64());
                let mut spar_errs = Vec::new();
                for sol in api::solve_batch(&problems, &spec).into_iter().flatten() {
                    if let Some(q) = &sol.barycenter {
                        spar_errs.push(l1_distance(&normalized_histogram(q), &truth));
                    }
                }
                let mut rand_errs = Vec::new();
                let mut nys_errs = Vec::new();
                for _ in 0..reps {
                    if let Ok(q) = rand_ibp(kernel, 3, &bs, &w, budget, &params, &mut rng) {
                        rand_errs.push(l1_distance(&normalized_histogram(&q), &truth));
                    }
                    let rank = ((budget / n as f64).ceil() as usize).max(1);
                    if let Ok(q) = nys_ibp(kernel, 3, &bs, &w, rank, &params, &mut rng) {
                        nys_errs.push(l1_distance(&normalized_histogram(&q), &truth));
                    }
                }
                for (name, errs) in [
                    ("nys-ibp", &nys_errs),
                    ("rand-ibp", &rand_errs),
                    ("spar-ibp", &spar_errs),
                ] {
                    let (mean, sd) = if errs.is_empty() {
                        (f64::NAN, 0.0)
                    } else {
                        mean_sd(errs)
                    };
                    let se = if errs.is_empty() { 0.0 } else { sd / (errs.len() as f64).sqrt() };
                    table.row(vec![
                        format!("{eps:.0e}"),
                        d.to_string(),
                        name.into(),
                        f(s_mult, 0),
                        f(mean, 4),
                        f(se, 4),
                    ]);
                    rows.push(row(vec![
                        ("eps", Json::num(eps)),
                        ("d", Json::num(d as f64)),
                        ("method", Json::str(name)),
                        ("s_mult", Json::num(s_mult)),
                        ("l1_err", Json::num(mean)),
                    ]));
                }
            }
        }
    }
    let text = format!(
        "Appendix Fig. 11 — barycenter L1 error vs s (n = {n}, {reps} reps, shared-cost artifacts)\n{}",
        table.render()
    );
    ExperimentOutput { id: "fig11", text, rows: Json::arr(rows) }
}
