//! Seeded violation (lint-pragma): a pragma with no reason string. It
//! still suppresses the unordered-iter finding under it — suppression
//! and hygiene are separate — but the missing reason is an error.

use std::collections::HashMap;

/// Counts values; order-irrelevant, but the pragma must say why.
pub fn count(values: &HashMap<u64, u64>) -> usize {
    // lint: allow(unordered-iter)
    values.values().count()
}
