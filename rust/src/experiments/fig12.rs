//! Appendix Fig. 12 — digit barycenters: IBP vs Spar-IBP over 15
//! randomly rescaled/translated glyphs per digit (our procedural-digit
//! substitution for MNIST), reporting the normalized L1 gap between the
//! two barycenters, CPU time, and an ASCII rendering.
//!
//! Every digit lives on the same pixel grid, so the cost and Gibbs
//! kernel are built exactly once as [`CostArtifacts`] and shared: the
//! exact IBP consumes the cached kernel (one reference per glyph, no
//! clones) and the Spar-IBP arm dispatches through
//! [`api::solve_batch`] on a shared [`CostHandle`].

use std::sync::Arc;
use std::time::Instant;

use super::common::row;
use super::{ExperimentOutput, Profile};
use crate::api::{self, Method as ApiMethod, OtProblem, SolverSpec};
use crate::data::digits::random_digit;
use crate::engine::{CostArtifacts, CostHandle, FormulationKey};
use crate::linalg::Mat;
use crate::metrics::{l1_distance, normalized_histogram};
use crate::ot::barycenter::ibp_barycenter_with;
use crate::ot::cost::{normalize_cost, sq_euclidean_cost};
use crate::ot::sinkhorn::SinkhornParams;
use crate::rng::Rng;
use crate::util::json::Json;
use crate::util::table::{f, Table};

/// ASCII-render a grid histogram (darkest = most mass).
pub fn ascii_render(q: &[f64], grid: usize) -> String {
    let shades = [' ', '.', ':', '+', '#', '@'];
    let max = q.iter().cloned().fold(0.0f64, f64::max).max(f64::MIN_POSITIVE);
    let mut out = String::new();
    // Downsample to <= 32 columns for readability.
    let step = grid.div_ceil(32);
    for y in (0..grid).step_by(step) {
        for x in (0..grid).step_by(step) {
            let mut acc = 0.0;
            for dy in 0..step.min(grid - y) {
                for dx in 0..step.min(grid - x) {
                    acc += q[(y + dy) * grid + (x + dx)];
                }
            }
            let level = (acc / (max * (step * step) as f64) * (shades.len() - 1) as f64)
                .round()
                .clamp(0.0, (shades.len() - 1) as f64) as usize;
            out.push(shades[level]);
        }
        out.push('\n');
    }
    out
}

/// Appendix Figure 12: digit barycenters, exact IBP vs Spar-IBP on one shared grid.
pub fn run(profile: Profile) -> ExperimentOutput {
    let grid = profile.pick(20, 32); // paper uses 64; 32 keeps full mode tractable on CPU
    let n = grid * grid;
    let per_digit = profile.pick(5, 15);
    let digits: Vec<u8> = profile.pick(vec![0u8, 3, 8], (0..10u8).collect());
    let eps = 1e-3 * 2.0; // relative to normalized cost
    let s_mult = 20.0;
    let params = SinkhornParams { delta: 1e-7, max_iters: 500, strict: false };

    // Shared pixel-grid support: ONE cost/kernel materialization serves
    // every digit and both solver arms.
    let pts: Vec<Vec<f64>> = (0..n)
        .map(|k| vec![(k % grid) as f64 / grid as f64, (k / grid) as f64 / grid as f64])
        .collect();
    let cost = Arc::new(normalize_cost(&sq_euclidean_cost(&pts, &pts)));
    let arts = CostArtifacts::from_dense(cost, eps, FormulationKey::Barycenter);
    let handle = CostHandle::new(arts.clone());
    let kernel: &Mat = &arts.kernel;

    let mut table = Table::new(&["digit", "ibp secs", "spar secs", "L1 gap", "speedup"]);
    let mut rows = Vec::new();
    let mut renders = String::new();
    let mut rng = Rng::seed_from(0xF172);
    for &digit in &digits {
        let bs: Vec<Vec<f64>> =
            (0..per_digit).map(|_| random_digit(digit, grid, &mut rng)).collect();
        let kernel_refs: Vec<&Mat> = vec![kernel; per_digit];
        let w = vec![1.0 / per_digit as f64; per_digit];

        let t0 = Instant::now();
        let exact = match ibp_barycenter_with(&kernel_refs, &bs, &w, &params) {
            Ok(sol) => sol,
            Err(_) => continue,
        };
        let ibp_secs = t0.elapsed().as_secs_f64();

        let t0 = Instant::now();
        let problem = OtProblem::barycenter(handle.clone(), bs, w, eps);
        let spec = SolverSpec::new(ApiMethod::SparIbp)
            .with_budget(s_mult)
            .with_tolerance(params.delta)
            .with_max_iters(params.max_iters)
            .with_seed(0xF172 ^ u64::from(digit));
        let approx = match api::solve_batch(&[problem], &spec).pop() {
            Some(Ok(sol)) => sol,
            _ => continue,
        };
        let spar_secs = t0.elapsed().as_secs_f64();

        let q_exact = normalized_histogram(&exact.q);
        let Some(q_spar) = approx.barycenter.as_deref() else { continue };
        let q_approx = normalized_histogram(q_spar);
        let gap = l1_distance(&q_exact, &q_approx);
        table.row(vec![
            digit.to_string(),
            f(ibp_secs, 3),
            f(spar_secs, 3),
            f(gap, 4),
            f(ibp_secs / spar_secs.max(1e-9), 1),
        ]);
        rows.push(row(vec![
            ("digit", Json::num(digit as f64)),
            ("ibp_secs", Json::num(ibp_secs)),
            ("spar_secs", Json::num(spar_secs)),
            ("l1_gap", Json::num(gap)),
        ]));
        if digit == digits[0] {
            renders.push_str(&format!(
                "digit {digit} IBP barycenter:\n{}\ndigit {digit} Spar-IBP barycenter:\n{}\n",
                ascii_render(&q_exact, grid),
                ascii_render(&q_approx, grid)
            ));
        }
    }
    let text = format!(
        "Appendix Fig. 12 — digit barycenters, {per_digit} glyphs/digit on a {grid}x{grid} grid (s = 20 s0(n), shared-cost artifacts)\n{}\n{}",
        table.render(),
        renders
    );
    ExperimentOutput { id: "fig12", text, rows: Json::arr(rows) }
}
