//! One shard of the worker pool: a bounded per-worker batch queue with
//! FIFO-submit / LIFO-pop scheduling, modeled on per-queue database
//! thread pools.
//!
//! The scheduler pushes routed batches at the BACK; the shard's own
//! workers pop from the BACK too (LIFO), so the batch a worker picks up
//! is the most recently routed one — the one whose cost fingerprint is
//! most likely still warm in the artifact cache and the CPU caches.
//! Stealers (see [`super::steal`]) take from the FRONT: the OLDEST
//! batch, i.e. the one that has waited longest and dominates tail
//! latency, while the cache-warm work stays home.
//!
//! The queue is bounded (in batches): a full shard blocks the scheduler
//! thread, which in turn stops draining the submission channel, so
//! backpressure propagates all the way to `submit` exactly as in the
//! single-queue design. Gauges (`depth`, `queued_max`, `busy`,
//! `routed`, `stolen`/`stolen_from`, `completed`/`failed`) and a
//! per-shard latency histogram feed
//! [`MetricsSnapshot`](super::MetricsSnapshot) through
//! [`ShardStats`](super::ShardStats).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::Duration;

use super::metrics::{LatencyHistogram, ShardStats};
use super::scheduler::Batch;
use crate::util::sync::{lock_unpoisoned, wait_timeout_unpoisoned, wait_unpoisoned};

/// Queue + lifecycle state behind the shard's mutex.
struct State {
    queue: VecDeque<Batch>,
    /// Set once the scheduler has drained and routed everything; no
    /// further pushes can arrive after this.
    closed: bool,
}

/// One per-worker bounded batch queue plus its gauges (see the module
/// docs for the scheduling discipline and the attribution rules).
pub(crate) struct Shard {
    state: Mutex<State>,
    /// Signals arriving work or the shard closing.
    work: Condvar,
    /// Signals queue space freeing up (for the bounded push).
    space: Condvar,
    /// Queue capacity in batches.
    cap: usize,
    /// Batches the scheduler routed here.
    pub(crate) routed: AtomicU64,
    /// Peak queue depth.
    pub(crate) queued_max: AtomicU64,
    /// Batches this shard's workers stole from other shards.
    pub(crate) stolen: AtomicU64,
    /// Batches stolen FROM this queue by other shards' workers.
    pub(crate) stolen_from: AtomicU64,
    /// Workers of this shard currently executing a batch.
    pub(crate) busy: AtomicU64,
    /// Jobs completed by this shard's workers.
    pub(crate) completed: AtomicU64,
    /// Jobs failed on this shard's workers.
    pub(crate) failed: AtomicU64,
    /// Latency of jobs executed by this shard's workers.
    pub(crate) latency: LatencyHistogram,
}

impl Shard {
    /// An open shard holding at most `cap` batches (minimum 1).
    pub(crate) fn new(cap: usize) -> Self {
        Shard {
            state: Mutex::new(State { queue: VecDeque::new(), closed: false }),
            work: Condvar::new(),
            space: Condvar::new(),
            cap: cap.max(1),
            routed: AtomicU64::new(0),
            queued_max: AtomicU64::new(0),
            stolen: AtomicU64::new(0),
            stolen_from: AtomicU64::new(0),
            busy: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            failed: AtomicU64::new(0),
            latency: LatencyHistogram::new(),
        }
    }

    /// Enqueue a routed batch at the back, blocking while the shard is
    /// full (bounded queue — this is how backpressure reaches the
    /// scheduler). Only the scheduler pushes, and it joins before the
    /// shard closes, so a push can never race `close`.
    pub(crate) fn push(&self, batch: Batch) {
        let mut state = lock_unpoisoned(&self.state);
        while state.queue.len() >= self.cap && !state.closed {
            state = wait_unpoisoned(&self.space, state);
        }
        state.queue.push_back(batch);
        let depth = state.queue.len() as u64;
        self.routed.fetch_add(1, Ordering::Relaxed);
        self.queued_max.fetch_max(depth, Ordering::Relaxed);
        drop(state);
        self.work.notify_one();
    }

    /// LIFO pop for the shard's own workers: the most recently routed
    /// batch (warmest fingerprints). Never blocks.
    pub(crate) fn pop_own(&self) -> Option<Batch> {
        let mut state = lock_unpoisoned(&self.state);
        let batch = state.queue.pop_back();
        if batch.is_some() {
            drop(state);
            self.space.notify_one();
        }
        batch
    }

    /// FIFO pop for stealers: the oldest queued batch (longest wait —
    /// the tail-latency victim). Never blocks.
    pub(crate) fn pop_stolen(&self) -> Option<Batch> {
        let mut state = lock_unpoisoned(&self.state);
        let batch = state.queue.pop_front();
        if batch.is_some() {
            self.stolen_from.fetch_add(1, Ordering::Relaxed);
            drop(state);
            self.space.notify_one();
        }
        batch
    }

    /// Current queue depth (a racy gauge — fine for victim selection
    /// and metrics).
    pub(crate) fn depth(&self) -> usize {
        lock_unpoisoned(&self.state).queue.len()
    }

    /// Whether the shard has been closed (no further pushes).
    pub(crate) fn is_closed(&self) -> bool {
        lock_unpoisoned(&self.state).closed
    }

    /// Whether the shard is closed AND drained — its workers may exit.
    pub(crate) fn is_drained(&self) -> bool {
        let state = lock_unpoisoned(&self.state);
        state.closed && state.queue.is_empty()
    }

    /// Park until work arrives, the shard closes, or `timeout` elapses
    /// (the timeout lets stealing workers re-scan other shards).
    pub(crate) fn wait_for_work(&self, timeout: Duration) {
        let state = lock_unpoisoned(&self.state);
        if !state.queue.is_empty() || state.closed {
            return;
        }
        let _ = wait_timeout_unpoisoned(&self.work, state, timeout);
    }

    /// Close the shard: wakes every parked worker and unblocks any
    /// pending bounded push. Called once the scheduler has exited.
    pub(crate) fn close(&self) {
        lock_unpoisoned(&self.state).closed = true;
        self.work.notify_all();
        self.space.notify_all();
    }

    /// Point-in-time gauges for [`MetricsSnapshot`](super::MetricsSnapshot).
    pub(crate) fn stats(&self, shard: usize) -> ShardStats {
        ShardStats {
            shard,
            depth: self.depth(),
            queued_max: self.queued_max.load(Ordering::Relaxed),
            busy: self.busy.load(Ordering::Relaxed),
            routed: self.routed.load(Ordering::Relaxed),
            stolen: self.stolen.load(Ordering::Relaxed),
            stolen_from: self.stolen_from.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            p99_latency: self.latency.quantile(0.99),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn empty_batch(id: u64) -> Batch {
        Batch { id, fingerprint: None, jobs: Vec::new() }
    }

    #[test]
    fn fifo_submit_lifo_pop_for_owners_fifo_for_stealers() {
        let shard = Shard::new(8);
        for id in 1..=3 {
            shard.push(empty_batch(id));
        }
        assert_eq!(shard.depth(), 3);
        // Own worker takes the newest…
        assert_eq!(shard.pop_own().unwrap().id, 3);
        // …a stealer takes the oldest.
        assert_eq!(shard.pop_stolen().unwrap().id, 1);
        assert_eq!(shard.pop_own().unwrap().id, 2);
        assert!(shard.pop_own().is_none());
        assert!(shard.pop_stolen().is_none());
        let stats = shard.stats(0);
        assert_eq!(stats.routed, 3);
        assert_eq!(stats.stolen_from, 1);
        assert_eq!(stats.queued_max, 3);
        assert_eq!(stats.depth, 0);
    }

    #[test]
    fn bounded_push_blocks_until_space_frees() {
        let shard = std::sync::Arc::new(Shard::new(1));
        shard.push(empty_batch(1));
        let pusher = {
            let shard = shard.clone();
            std::thread::spawn(move || shard.push(empty_batch(2)))
        };
        // The pusher is blocked on the full queue; popping frees it.
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(shard.depth(), 1);
        assert_eq!(shard.pop_own().unwrap().id, 1);
        pusher.join().unwrap();
        assert_eq!(shard.pop_own().unwrap().id, 2);
    }

    #[test]
    fn close_wakes_parked_workers_and_marks_drained() {
        let shard = std::sync::Arc::new(Shard::new(4));
        assert!(!shard.is_closed());
        let parked = {
            let shard = shard.clone();
            std::thread::spawn(move || shard.wait_for_work(Duration::from_secs(10)))
        };
        std::thread::sleep(Duration::from_millis(20));
        shard.close();
        parked.join().unwrap(); // woke well before the 10 s timeout
        assert!(shard.is_closed());
        assert!(shard.is_drained());
    }
}
