//! Minimal HTTP/1.1 client for loopback service-to-service calls: the
//! balancer's proxy leg and health probes, and the load generator's
//! replay connections.
//!
//! Deliberately narrow, mirroring [`super::http`] on the other side of
//! the wire: one request per connection (`connection: close`), bodies
//! delimited by `content-length`, bounded reads everywhere. No TLS, no
//! chunked encoding, no redirects — the peers are our own gateways.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

/// Largest accepted response head line and body. A misbehaving peer can
/// never make a client buffer more than this.
const MAX_LINE: usize = 16 * 1024;
const MAX_BODY: usize = 16 * 1024 * 1024;

/// One parsed upstream response: status, lowercased headers in arrival
/// order, and the raw body bytes (relayed verbatim by the balancer —
/// the bitwise-transparency contract rides on never re-encoding them).
#[derive(Clone, Debug)]
pub struct ClientResponse {
    /// HTTP status code.
    pub status: u16,
    /// `(lowercased-name, value)` pairs in arrival order.
    pub headers: Vec<(String, String)>,
    /// Response body bytes, verbatim.
    pub body: Vec<u8>,
}

impl ClientResponse {
    /// First value of header `name` (give it lowercased), if present.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
    }

    /// The `retry-after` delay, when present and parseable (seconds
    /// form only — our gateways never emit the HTTP-date form).
    pub fn retry_after(&self) -> Option<Duration> {
        let seconds: f64 = self.header("retry-after")?.trim().parse().ok()?;
        (seconds.is_finite() && seconds >= 0.0).then(|| Duration::from_secs_f64(seconds))
    }
}

/// Perform one request against `addr` and read the full response. The
/// connection is fresh and closed afterwards (`connection: close`), so
/// every call observes the peer's current accept/drain state. `body`
/// is sent as `application/json` when present.
pub fn request(
    addr: SocketAddr,
    method: &str,
    path: &str,
    body: Option<&[u8]>,
    connect_timeout: Duration,
    io_timeout: Duration,
) -> std::io::Result<ClientResponse> {
    let mut stream = TcpStream::connect_timeout(&addr, connect_timeout)?;
    stream.set_read_timeout(Some(io_timeout))?;
    stream.set_write_timeout(Some(io_timeout))?;
    let _ = stream.set_nodelay(true);
    let mut head = format!("{method} {path} HTTP/1.1\r\nhost: {addr}\r\nconnection: close\r\n");
    if let Some(body) = body {
        head.push_str("content-type: application/json\r\n");
        head.push_str(&format!("content-length: {}\r\n", body.len()));
    }
    head.push_str("\r\n");
    stream.write_all(head.as_bytes())?;
    if let Some(body) = body {
        stream.write_all(body)?;
    }
    stream.flush()?;
    read_response(&mut BufReader::new(stream))
}

fn bad_data(what: &str) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, what.to_string())
}

/// Parse one response from `reader`: status line, headers, then a
/// `content-length` body (or read-to-close when the header is absent).
pub fn read_response<R: BufRead>(reader: &mut R) -> std::io::Result<ClientResponse> {
    let line = read_line(reader)?.ok_or_else(|| bad_data("empty response"))?;
    let mut parts = line.split_whitespace();
    let status: u16 = match (parts.next(), parts.next()) {
        (Some(version), Some(code)) if version.starts_with("HTTP/1.") => {
            code.parse().map_err(|_| bad_data("bad status code"))?
        }
        _ => return Err(bad_data("bad status line")),
    };
    let mut headers: Vec<(String, String)> = Vec::new();
    loop {
        let line = read_line(reader)?.ok_or_else(|| bad_data("truncated headers"))?;
        if line.is_empty() {
            break;
        }
        let Some((name, value)) = line.split_once(':') else {
            return Err(bad_data("malformed header line"));
        };
        headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
    }
    let response = ClientResponse { status, headers, body: Vec::new() };
    let body = match response.header("content-length") {
        Some(declared) => {
            let declared: usize =
                declared.parse().map_err(|_| bad_data("bad content-length"))?;
            if declared > MAX_BODY {
                return Err(bad_data("response body exceeds the size cap"));
            }
            let mut body = vec![0u8; declared];
            reader.read_exact(&mut body)?;
            body
        }
        None => {
            let mut body = Vec::new();
            reader.take(MAX_BODY as u64 + 1).read_to_end(&mut body)?;
            if body.len() > MAX_BODY {
                return Err(bad_data("response body exceeds the size cap"));
            }
            body
        }
    };
    Ok(ClientResponse { body, ..response })
}

/// One CRLF/LF-terminated line of at most [`MAX_LINE`] bytes
/// (terminator excluded); `Ok(None)` is EOF before any byte.
fn read_line<R: BufRead>(reader: &mut R) -> std::io::Result<Option<String>> {
    let mut line: Vec<u8> = Vec::new();
    loop {
        let mut byte = [0u8; 1];
        match reader.read(&mut byte) {
            Ok(0) => {
                return if line.is_empty() {
                    Ok(None)
                } else {
                    Err(bad_data("truncated line"))
                };
            }
            Ok(_) => {
                if byte[0] == b'\n' {
                    if line.last() == Some(&b'\r') {
                        line.pop();
                    }
                    let line =
                        String::from_utf8(line).map_err(|_| bad_data("non-UTF-8 head"))?;
                    return Ok(Some(line));
                }
                if line.len() >= MAX_LINE {
                    return Err(bad_data("head line exceeds the size cap"));
                }
                line.push(byte[0]);
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_a_gateway_style_response() {
        let raw: &[u8] = b"HTTP/1.1 429 Too Many Requests\r\ncontent-type: application/json\r\n\
                           content-length: 16\r\nretry-after: 1\r\n\r\n{\"error\":\"busy\"}";
        let resp = read_response(&mut BufReader::new(raw)).unwrap();
        assert_eq!(resp.status, 429);
        assert_eq!(resp.header("content-type"), Some("application/json"));
        assert_eq!(resp.retry_after(), Some(Duration::from_secs(1)));
        assert_eq!(resp.body, b"{\"error\":\"busy\"}");
    }

    #[test]
    fn missing_content_length_reads_to_close() {
        let raw: &[u8] = b"HTTP/1.1 200 OK\r\n\r\npartial";
        let resp = read_response(&mut BufReader::new(raw)).unwrap();
        assert_eq!(resp.status, 200);
        assert_eq!(resp.body, b"partial");
        assert!(resp.retry_after().is_none());
    }

    #[test]
    fn malformed_heads_are_loud_io_errors() {
        for raw in [
            &b""[..],
            b"NOT HTTP\r\n\r\n",
            b"HTTP/1.1 abc\r\n\r\n",
            b"HTTP/1.1 200 OK\r\nno-colon-here\r\n\r\n",
            b"HTTP/1.1 200 OK\r\ncontent-length: xyz\r\n\r\n",
            b"HTTP/1.1 200 OK\r\ncontent-length: 99\r\n\r\nshort",
        ] {
            let err = read_response(&mut BufReader::new(raw));
            assert!(err.is_err(), "{raw:?}");
        }
    }
}
