//! Algorithm 1 — the classical Sinkhorn algorithm for entropic OT,
//! with the paper's stopping rule `‖u⁽ᵗ⁾−u⁽ᵗ⁻¹⁾‖₁+‖v⁽ᵗ⁾−v⁽ᵗ⁻¹⁾‖₁ ≤ δ`.

use super::{objective, SinkhornSolution};
use crate::error::{Error, Result};
use crate::linalg::{l1_diff, Mat};

/// Common Sinkhorn parameters (paper defaults: δ = 1e-6, 1000 iters).
#[derive(Clone, Debug)]
pub struct SinkhornParams {
    /// Stopping threshold δ on the L1 scaling displacement.
    pub delta: f64,
    /// Maximum number of iterations.
    pub max_iters: usize,
    /// Error instead of returning a best-effort solution when the
    /// iteration cap is hit.
    pub strict: bool,
}

impl Default for SinkhornParams {
    fn default() -> Self {
        SinkhornParams { delta: 1e-6, max_iters: 1000, strict: false }
    }
}

/// Guard against division by (numerically) zero: the scaling updates
/// divide by `K v`, which underflows when ε is small. Matches POT's
/// behaviour of clamping rather than emitting inf.
#[inline(always)]
pub(crate) fn safe_div(num: f64, den: f64) -> f64 {
    if den.abs() < 1e-300 {
        if num == 0.0 {
            0.0
        } else {
            num / 1e-300
        }
    } else {
        num / den
    }
}

fn validate(kernel: &Mat, a: &[f64], b: &[f64]) -> Result<()> {
    if kernel.rows() != a.len() || kernel.cols() != b.len() {
        return Err(Error::Dimension(format!(
            "kernel {}x{} vs a[{}], b[{}]",
            kernel.rows(),
            kernel.cols(),
            a.len(),
            b.len()
        )));
    }
    if a.iter().any(|&x| x < 0.0) || b.iter().any(|&x| x < 0.0) {
        return Err(Error::InvalidParam("marginals must be non-negative".into()));
    }
    Ok(())
}

/// Run Algorithm 1 and evaluate the entropic OT objective (Eq. 6).
///
/// * `kernel` — Gibbs kernel `K = exp(-C/ε)` (or a sparsified proxy).
/// * `cost` — ground cost matrix used for objective evaluation.
/// * `a`, `b` — probability histograms.
pub fn sinkhorn_ot(
    kernel: &Mat,
    cost: &Mat,
    a: &[f64],
    b: &[f64],
    eps: f64,
    params: &SinkhornParams,
) -> Result<SinkhornSolution> {
    let (u, v, iterations, displacement, converged) = sinkhorn_scalings(kernel, a, b, 1.0, params)?;
    let objective = objective::ot_objective_dense(kernel, cost, &u, &v, eps);
    if !objective.is_finite() {
        return Err(Error::Numerical(format!(
            "OT objective is not finite (eps={eps}); consider rescaling the cost"
        )));
    }
    Ok(SinkhornSolution { u, v, objective, iterations, displacement, converged })
}

/// The shared scaling loop for Algorithms 1 and 2.
///
/// `rho = 1` is Algorithm 1; `rho = λ/(λ+ε)` is Algorithm 2. Returns
/// `(u, v, iterations, displacement, converged)`.
pub fn sinkhorn_scalings(
    kernel: &Mat,
    a: &[f64],
    b: &[f64],
    rho: f64,
    params: &SinkhornParams,
) -> Result<(Vec<f64>, Vec<f64>, usize, f64, bool)> {
    validate(kernel, a, b)?;
    let n = a.len();
    let m = b.len();
    let mut u = vec![1.0; n];
    let mut v = vec![1.0; m];
    let mut u_prev = vec![1.0; n];
    let mut v_prev = vec![1.0; m];
    let mut displacement = f64::INFINITY;
    let mut iters = 0;
    while iters < params.max_iters {
        iters += 1;
        u_prev.copy_from_slice(&u);
        v_prev.copy_from_slice(&v);
        // u = (a ./ K v)^rho
        let kv = kernel.matvec(&v);
        for i in 0..n {
            let val = safe_div(a[i], kv[i]);
            u[i] = if rho == 1.0 { val } else { val.powf(rho) };
        }
        // v = (b ./ K^T u)^rho
        let ktu = kernel.matvec_t(&u);
        for j in 0..m {
            let val = safe_div(b[j], ktu[j]);
            v[j] = if rho == 1.0 { val } else { val.powf(rho) };
        }
        if u.iter().chain(v.iter()).any(|x| !x.is_finite()) {
            return Err(Error::Numerical(format!(
                "scalings diverged at iteration {iters}"
            )));
        }
        displacement = l1_diff(&u, &u_prev) + l1_diff(&v, &v_prev);
        if displacement <= params.delta {
            return Ok((u, v, iters, displacement, true));
        }
    }
    if params.strict {
        return Err(Error::NotConverged { iters, err: displacement });
    }
    Ok((u, v, iters, displacement, false))
}

/// Dense transport plan `T = diag(u) K diag(v)`.
pub fn transport_plan(kernel: &Mat, u: &[f64], v: &[f64]) -> Mat {
    Mat::from_fn(kernel.rows(), kernel.cols(), |i, j| {
        u[i] * kernel.get(i, j) * v[j]
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ot::cost::{gibbs_kernel, sq_euclidean_cost};

    fn toy_problem(n: usize, eps: f64) -> (Mat, Mat, Vec<f64>, Vec<f64>) {
        let pts: Vec<Vec<f64>> = (0..n)
            .map(|i| vec![(i as f64 * 0.618).fract(), (i as f64 * 0.383).fract()])
            .collect();
        let cost = sq_euclidean_cost(&pts, &pts);
        let kernel = gibbs_kernel(&cost, eps);
        let a: Vec<f64> = (0..n).map(|i| 1.0 + (i % 3) as f64).collect();
        let sa: f64 = a.iter().sum();
        let a: Vec<f64> = a.iter().map(|x| x / sa).collect();
        let b: Vec<f64> = (0..n).map(|i| 1.0 + ((i + 1) % 4) as f64).collect();
        let sb: f64 = b.iter().sum();
        let b: Vec<f64> = b.iter().map(|x| x / sb).collect();
        (kernel, cost, a, b)
    }

    #[test]
    fn converges_and_satisfies_marginals() {
        let (kernel, cost, a, b) = toy_problem(32, 0.1);
        let sol = sinkhorn_ot(&kernel, &cost, &a, &b, 0.1, &SinkhornParams::default()).unwrap();
        assert!(sol.converged, "displacement {}", sol.displacement);
        let plan = transport_plan(&kernel, &sol.u, &sol.v);
        let rows = plan.row_sums();
        let cols = plan.col_sums();
        for (r, want) in rows.iter().zip(&a) {
            assert!((r - want).abs() < 1e-5, "row marginal {r} vs {want}");
        }
        for (c, want) in cols.iter().zip(&b) {
            assert!((c - want).abs() < 1e-5, "col marginal {c} vs {want}");
        }
    }

    #[test]
    fn identical_marginals_give_near_diagonal_plan() {
        let (kernel, cost, a, _) = toy_problem(16, 0.01);
        let sol = sinkhorn_ot(&kernel, &cost, &a, &a, 0.01, &SinkhornParams::default()).unwrap();
        // With identical marginals and small eps the objective ≈ -eps*H(diag plan) which is
        // small; transport cost itself must be near zero.
        let plan = transport_plan(&kernel, &sol.u, &sol.v);
        let transport_cost: f64 = (0..16)
            .map(|i| (0..16).map(|j| plan.get(i, j) * cost.get(i, j)).sum::<f64>())
            .sum();
        // Entropic blur at eps = 0.01 leaves a little off-diagonal mass;
        // the transport cost must still be near zero.
        assert!(transport_cost < 1e-2, "cost {transport_cost}");
    }

    #[test]
    fn objective_decreases_with_distance_between_measures() {
        // Moving b closer to a must not increase the OT objective.
        let n = 24;
        let pts: Vec<Vec<f64>> = (0..n).map(|i| vec![i as f64 / n as f64]).collect();
        let cost = sq_euclidean_cost(&pts, &pts);
        let eps = 0.05;
        let kernel = gibbs_kernel(&cost, eps);
        let gauss = |mu: f64| -> Vec<f64> {
            let w: Vec<f64> = (0..n)
                .map(|i| (-(pts[i][0] - mu).powi(2) / 0.02).exp())
                .collect();
            let s: f64 = w.iter().sum();
            w.iter().map(|x| x / s).collect()
        };
        let a = gauss(0.3);
        let params = SinkhornParams::default();
        let near = sinkhorn_ot(&kernel, &cost, &a, &gauss(0.35), eps, &params).unwrap();
        let far = sinkhorn_ot(&kernel, &cost, &a, &gauss(0.7), eps, &params).unwrap();
        assert!(near.objective < far.objective);
    }

    #[test]
    fn dimension_mismatch_rejected() {
        let (kernel, cost, a, b) = toy_problem(8, 0.1);
        let bad_a = &a[..4];
        let err = sinkhorn_ot(&kernel, &cost, bad_a, &b, 0.1, &SinkhornParams::default());
        assert!(matches!(err, Err(Error::Dimension(_))));
    }

    #[test]
    fn negative_marginal_rejected() {
        let (kernel, cost, mut a, b) = toy_problem(8, 0.1);
        a[0] = -0.1;
        let err = sinkhorn_ot(&kernel, &cost, &a, &b, 0.1, &SinkhornParams::default());
        assert!(matches!(err, Err(Error::InvalidParam(_))));
    }

    #[test]
    fn strict_mode_errors_when_capped() {
        let (kernel, _cost, a, b) = toy_problem(32, 0.001);
        let params = SinkhornParams { delta: 0.0, max_iters: 3, strict: true };
        let err = sinkhorn_scalings(&kernel, &a, &b, 1.0, &params);
        assert!(matches!(err, Err(Error::NotConverged { .. })));
    }

    #[test]
    fn safe_div_guards() {
        assert_eq!(safe_div(0.0, 0.0), 0.0);
        assert!(safe_div(1.0, 0.0).is_finite());
        assert_eq!(safe_div(6.0, 3.0), 2.0);
    }
}
