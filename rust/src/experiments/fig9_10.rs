//! Appendix Figs. 9 & 10 — asymptotic convergence: RMAE versus n at
//! fixed budget s = 8·s₀(n) (OT under C1-C3; UOT under R1-R3).

use super::common::{
    exact_ot, exact_uot, ot_cost, rmae_over_reps, row, run_method_ot, run_method_uot,
    wfr_cost_at_density, Method,
};
use super::{ExperimentOutput, Profile};
use crate::data::synthetic::{instance, Scenario, SparsityRegime};
use crate::rng::Rng;
use crate::util::json::Json;
use crate::util::table::{f, Table};

/// Appendix Figure 9: RMAE(OT) vs n at fixed s = 8·s₀(n) (asymptotic rate check).
pub fn run_fig9(profile: Profile) -> ExperimentOutput {
    let ns: Vec<usize> = profile.pick(vec![100, 200, 400, 800], vec![100, 200, 400, 800, 1600, 3200, 6400]);
    let reps = profile.reps(5, 100);
    let d = 5;
    let eps = 0.1;
    let s_mult = 8.0;
    let mut table = Table::new(&["scenario", "n", "method", "rmae", "se"]);
    let mut rows = Vec::new();
    let mut rng = Rng::seed_from(0xF169);
    for scenario in Scenario::all() {
        for &n in &ns {
            let inst = instance(scenario, n, d, 1.0, 1.0, &mut rng);
            let cost = ot_cost(&inst.points);
            let Ok(truth) = exact_ot(&cost, &inst.a, &inst.b, eps) else { continue };
            for method in Method::all() {
                let (rmae, se, _) = rmae_over_reps(
                    reps,
                    truth,
                    |r| run_method_ot(method, &cost, &inst.a, &inst.b, eps, s_mult, r),
                    &mut rng,
                );
                table.row(vec![
                    scenario.name().into(),
                    n.to_string(),
                    method.name().into(),
                    f(rmae, 4),
                    f(se, 4),
                ]);
                rows.push(row(vec![
                    ("scenario", Json::str(scenario.name())),
                    ("n", Json::num(n as f64)),
                    ("method", Json::str(method.name())),
                    ("rmae", Json::num(rmae)),
                ]));
            }
        }
    }
    let text = format!(
        "Appendix Fig. 9 — RMAE(OT) vs n (s = 8 s0(n), eps = {eps}, {reps} reps)\n{}",
        table.render()
    );
    ExperimentOutput { id: "fig9", text, rows: Json::arr(rows) }
}

/// Appendix Figure 10: RMAE(UOT) vs n at fixed s = 8·s₀(n).
pub fn run_fig10(profile: Profile) -> ExperimentOutput {
    let ns: Vec<usize> = profile.pick(vec![100, 200, 400], vec![100, 200, 400, 800, 1600, 3200]);
    let reps = profile.reps(5, 100);
    let d = 5;
    let (lambda, eps) = (0.1, 0.1);
    let s_mult = 8.0;
    let mut table = Table::new(&["regime", "n", "method", "rmae", "se"]);
    let mut rows = Vec::new();
    let mut rng = Rng::seed_from(0xF170);
    for regime in SparsityRegime::all() {
        for &n in &ns {
            let inst = instance(Scenario::C1, n, d, 5.0, 3.0, &mut rng);
            let cost = wfr_cost_at_density(&inst.points, regime.density());
            let Ok(truth) = exact_uot(&cost, &inst.a, &inst.b, lambda, eps) else { continue };
            for method in Method::all() {
                let (rmae, se, _) = rmae_over_reps(
                    reps,
                    truth,
                    |r| run_method_uot(method, &cost, &inst.a, &inst.b, lambda, eps, s_mult, r),
                    &mut rng,
                );
                table.row(vec![
                    regime.name().into(),
                    n.to_string(),
                    method.name().into(),
                    f(rmae, 4),
                    f(se, 4),
                ]);
                rows.push(row(vec![
                    ("regime", Json::str(regime.name())),
                    ("n", Json::num(n as f64)),
                    ("method", Json::str(method.name())),
                    ("rmae", Json::num(rmae)),
                ]));
            }
        }
    }
    let text = format!(
        "Appendix Fig. 10 — RMAE(UOT) vs n (s = 8 s0(n), eps = lambda = 0.1, {reps} reps)\n{}",
        table.render()
    );
    ExperimentOutput { id: "fig10", text, rows: Json::arr(rows) }
}
