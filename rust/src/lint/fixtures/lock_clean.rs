//! Clean twin of `lock_bad.rs`: the poison-recovering helper keeps a
//! peer's panic from cascading.

use crate::util::sync::lock_unpoisoned;
use std::sync::Mutex;

/// Drains a shared queue, surviving a poisoned lock.
pub fn drain(queue: &Mutex<Vec<u64>>) -> Vec<u64> {
    let mut guard = lock_unpoisoned(queue);
    guard.split_off(0)
}
