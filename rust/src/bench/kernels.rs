//! `repro bench kernels`: the kernel-level hot-loop trajectory.
//!
//! An n-sweep over the loops the paper's Õ(n) claim lives or dies on:
//!
//! * **dense-build** — the tiled `sq_euclidean_cost` / `gibbs_kernel`
//!   builders (O(n²), the setup cost every dense baseline pays);
//! * **sparse-lse** — `CsrMatrix::row_lse` / `col_lse`, the log engine's
//!   per-iteration O(nnz) sweeps over the materialized log-kernel;
//! * **mult-scaling** — the fused multiplicative sparse loop vs the
//!   log-domain loop at a fixed iteration count (δ = 0 so neither stops
//!   early: pure per-iteration cost, not convergence speed);
//! * **solve** — end-to-end `api::solve` for sinkhorn vs spar-sink vs
//!   spar-sink-log on the same balanced problem.
//!
//! Emits `BENCH_kernels.json` (same convention as
//! `BENCH_coordinator.json`): the committed artifact is a schema seed —
//! regenerate on real hardware and report deltas in the PR.

use std::hint::black_box;

use crate::api::{self, Method, OtProblem, SolverSpec};
use crate::linalg::Mat;
use crate::ot::cost::{gibbs_kernel, normalize_cost, sq_euclidean_cost};
use crate::ot::sinkhorn::SinkhornParams;
use crate::rng::Rng;
use crate::solvers::log_sparse::log_sparse_scalings;
use crate::solvers::sketch_budget;
use crate::solvers::sparse_loop::sparse_scalings;
use crate::sparse::{poisson_sparsify_ot, CsrMatrix};
use crate::util::json::Json;

use super::{BenchResult, Bencher};

/// Workload parameters for one kernel bench run.
#[derive(Clone, Debug)]
pub struct BenchConfig {
    /// Support sizes to sweep (square n × n problems).
    pub sizes: Vec<usize>,
    /// Entropic regularization ε (cost normalized to max 1).
    pub eps: f64,
    /// Sketch budget multiplier (`s = s_multiplier · s₀(n)` via
    /// [`sketch_budget`]).
    pub s_multiplier: f64,
    /// Fixed iteration count for the mult-vs-log scaling contrast.
    pub scaling_iters: usize,
    /// Use the low-budget [`Bencher::quick`] runner.
    pub quick: bool,
}

impl BenchConfig {
    /// The default sweep for the committed artifact.
    pub fn full() -> Self {
        BenchConfig {
            sizes: vec![200, 400, 800],
            eps: 0.05,
            s_multiplier: 2.0,
            scaling_iters: 20,
            quick: false,
        }
    }

    /// A seconds-scale configuration for CI smoke runs.
    pub fn quick() -> Self {
        BenchConfig {
            sizes: vec![64, 128],
            eps: 0.05,
            s_multiplier: 2.0,
            scaling_iters: 5,
            quick: true,
        }
    }
}

/// One n-sized problem instance shared by every group: deterministic
/// 2-d point clouds, normalized squared-Euclidean cost, Gibbs kernel,
/// uniform-ish marginals, and an importance sketch at the standard
/// budget. Everything is seeded, so reruns measure the same work.
struct Fixture {
    n: usize,
    xs: Vec<Vec<f64>>,
    ys: Vec<Vec<f64>>,
    cost: Mat,
    a: Vec<f64>,
    b: Vec<f64>,
    sketch: CsrMatrix,
}

impl Fixture {
    fn build(n: usize, cfg: &BenchConfig) -> Self {
        let mut rng = Rng::seed_from(41 + n as u64);
        let point = |rng: &mut Rng| vec![rng.uniform(), rng.uniform()];
        let xs: Vec<Vec<f64>> = (0..n).map(|_| point(&mut rng)).collect();
        let ys: Vec<Vec<f64>> = (0..n).map(|_| point(&mut rng)).collect();
        let cost = normalize_cost(&sq_euclidean_cost(&xs, &ys));
        let kernel = gibbs_kernel(&cost, cfg.eps);
        let mass = |rng: &mut Rng| {
            let w: Vec<f64> = (0..n).map(|_| 0.5 + rng.uniform()).collect();
            let total: f64 = w.iter().sum();
            w.into_iter().map(|x| x / total).collect::<Vec<f64>>()
        };
        let a = mass(&mut rng);
        let b = mass(&mut rng);
        let s = sketch_budget(cfg.s_multiplier, n, n);
        let (sketch, _) = poisson_sparsify_ot(
            |i, j| kernel.get(i, j),
            |i, j| cost.get(i, j),
            &a,
            &b,
            s,
            1.0,
            &mut rng,
        )
        .expect("bench fixture sketch");
        Fixture { n, xs, ys, cost, a, b, sketch }
    }
}

/// One emitted row: the group/name/n identity plus the bench stats.
fn row(group: &str, fx: &Fixture, nnz: usize, r: &BenchResult) -> Json {
    Json::obj(vec![
        ("group", Json::str(group)),
        ("name", Json::str(r.name.clone())),
        ("n", Json::num(fx.n as f64)),
        ("nnz", Json::num(nnz as f64)),
        ("mean_us", Json::num(r.mean().as_secs_f64() * 1e6)),
        ("median_us", Json::num(r.median().as_secs_f64() * 1e6)),
        ("sd_us", Json::num(r.stddev().as_secs_f64() * 1e6)),
        ("samples", Json::num(r.samples.len() as f64)),
    ])
}

/// Run the kernel bench sweep and return the `BENCH_kernels.json`
/// document. Prints one line per benchmark as it completes.
pub fn run(cfg: &BenchConfig) -> Json {
    let mut rows = Vec::new();
    for &n in &cfg.sizes {
        let fx = Fixture::build(n, cfg);
        let dense_nnz = n * n;
        let sparse_nnz = fx.sketch.nnz();
        let mut b = if cfg.quick {
            Bencher::quick()
        } else {
            Bencher::default()
        };

        // dense-build: the tiled O(n²) builders.
        let r = b.bench(format!("dense-build/sq_euclidean_cost/n={n}"), || {
            black_box(sq_euclidean_cost(black_box(&fx.xs), black_box(&fx.ys)));
        });
        rows.push(row("dense-build", &fx, dense_nnz, r));
        let r = b.bench(format!("dense-build/gibbs_kernel/n={n}"), || {
            black_box(gibbs_kernel(black_box(&fx.cost), cfg.eps));
        });
        rows.push(row("dense-build", &fx, dense_nnz, r));

        // sparse-lse: the log engine's O(nnz) per-iteration sweeps.
        let g: Vec<f64> = (0..n).map(|j| (j as f64 * 0.37).sin()).collect();
        let r = b.bench(format!("sparse-lse/row_lse/n={n}"), || {
            black_box(fx.sketch.row_lse(black_box(&g)));
        });
        rows.push(row("sparse-lse", &fx, sparse_nnz, r));
        let r = b.bench(format!("sparse-lse/col_lse/n={n}"), || {
            black_box(fx.sketch.col_lse(black_box(&g)));
        });
        rows.push(row("sparse-lse", &fx, sparse_nnz, r));

        // mult-scaling: fused multiplicative loop vs log loop at a
        // fixed iteration count (δ = 0 disables early stopping).
        let params = SinkhornParams { delta: 0.0, max_iters: cfg.scaling_iters, strict: false };
        let r = b.bench(format!("mult-scaling/sparse_scalings/n={n}"), || {
            black_box(
                sparse_scalings(black_box(&fx.sketch), &fx.a, &fx.b, 1.0, &params)
                    .expect("mult scaling runs"),
            );
        });
        rows.push(row("mult-scaling", &fx, sparse_nnz, r));
        let r = b.bench(format!("mult-scaling/log_sparse_scalings/n={n}"), || {
            black_box(
                log_sparse_scalings(black_box(&fx.sketch), &fx.a, &fx.b, 1.0, cfg.eps, &params)
                    .expect("log scaling runs"),
            );
        });
        rows.push(row("mult-scaling", &fx, sparse_nnz, r));

        // solve: end-to-end API solves, dense baseline vs the sketches.
        for method in [Method::Sinkhorn, Method::SparSink, Method::SparSinkLog] {
            let problem =
                OtProblem::balanced(fx.cost.clone(), fx.a.clone(), fx.b.clone(), cfg.eps);
            let spec = SolverSpec::new(method)
                .with_budget(cfg.s_multiplier)
                .with_seed(17 + fx.n as u64);
            let r = b.bench(format!("solve/{}/n={n}", method.name()), || {
                black_box(api::solve(black_box(&problem), &spec).expect("bench solve"));
            });
            let nnz = if method == Method::Sinkhorn {
                dense_nnz
            } else {
                sparse_nnz
            };
            rows.push(row("solve", &fx, nnz, r));
        }
    }
    Json::obj(vec![
        ("bench", Json::str("kernels")),
        (
            "workload",
            Json::obj(vec![
                ("sizes", Json::arr(cfg.sizes.iter().map(|&n| Json::num(n as f64)).collect())),
                ("eps", Json::num(cfg.eps)),
                ("s_multiplier", Json::num(cfg.s_multiplier)),
                ("scaling_iters", Json::num(cfg.scaling_iters as f64)),
                ("quick", Json::Bool(cfg.quick)),
            ]),
        ),
        ("rows", Json::arr(rows)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixture_is_deterministic() {
        // A generous multiplier keeps the expected sketch size well
        // above zero at this tiny n (s₀(16) ≈ 0.9, so the standard 2.0
        // would make the nonempty assertion a coin flip).
        let cfg = BenchConfig { sizes: vec![16], s_multiplier: 50.0, ..BenchConfig::quick() };
        let a = Fixture::build(16, &cfg);
        let b = Fixture::build(16, &cfg);
        assert_eq!(a.cost.as_slice(), b.cost.as_slice());
        assert_eq!(a.sketch.nnz(), b.sketch.nnz());
        assert_eq!(a.a, b.a);
        assert!(a.sketch.nnz() > 0);
        assert!(a.sketch.nnz() < 16 * 16);
    }

    #[test]
    fn tiny_sweep_covers_every_group_and_method() {
        // s_multiplier is generous for the same reason as above: at
        // n = 12 the standard budget rounds to an almost-empty sketch.
        let cfg = BenchConfig {
            sizes: vec![12],
            eps: 0.05,
            s_multiplier: 25.0,
            scaling_iters: 2,
            quick: true,
        };
        let doc = run(&cfg);
        assert_eq!(doc.get("bench").and_then(Json::as_str), Some("kernels"));
        let rows = doc.get("rows").expect("rows").items();
        // 2 dense-build + 2 sparse-lse + 2 mult-scaling + 3 solve rows.
        assert_eq!(rows.len(), 9);
        for group in ["dense-build", "sparse-lse", "mult-scaling", "solve"] {
            assert!(
                rows.iter().any(|r| r.get("group").and_then(Json::as_str) == Some(group)),
                "missing group {group}"
            );
        }
        for method in ["sinkhorn", "spar-sink", "spar-sink-log"] {
            assert!(
                rows.iter().any(|r| {
                    r.get("name").and_then(Json::as_str).is_some_and(|s| s.contains(method))
                }),
                "missing solve method {method}"
            );
        }
        for r in &rows {
            assert_eq!(r.get("n").and_then(Json::as_f64), Some(12.0));
            assert!(r.get("samples").and_then(Json::as_f64).unwrap_or(0.0) >= 1.0);
            assert!(r.get("mean_us").and_then(Json::as_f64).unwrap_or(-1.0) >= 0.0);
        }
    }
}
