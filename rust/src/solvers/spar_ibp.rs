//! Algorithm 6 — Spar-IBP: importance-sparsified iterative Bregman
//! projection for fixed-support Wasserstein barycenters.
//!
//! Each kernel `K_k` is Poisson-sparsified with the probability of
//! Appendix A.2: `p_{k,ij} = √(b_{k,j}) / (n Σ_j √(b_{k,j}))` — the
//! unknown barycenter is replaced by the uniform initial `q⁽⁰⁾ = 1/n`,
//! making row probabilities constant. The sparse sketches then drive the
//! same IBP loop (Algorithm 5) through the `KernelOp` abstraction.

use super::backend::BackendKind;
use crate::api::{Formulation, OtProblem, SolverSpec};
use crate::error::{Error, Result};
use crate::linalg::Mat;
use crate::ot::barycenter::{ibp_barycenter_with, BarycenterSolution};
use crate::ot::sinkhorn::SinkhornParams;
use crate::rng::Rng;
use crate::sparse::{
    poisson_sparsify_ibp_logk, poisson_sparsify_with, CsrMatrix, SparsifyStats,
};

/// Result with per-kernel sparsification stats.
#[derive(Clone, Debug)]
pub struct SparIbpSolution {
    /// The barycenter histogram and IBP loop diagnostics.
    pub solution: BarycenterSolution,
    /// One sparsifier diagnostic per input kernel.
    pub stats: Vec<SparsifyStats>,
}

/// Sparsify one IBP kernel with the Appendix A.2 probability.
pub fn sparsify_ibp_kernel(
    kernel: &Mat,
    b_k: &[f64],
    s: f64,
    rng: &mut Rng,
) -> Result<(CsrMatrix, SparsifyStats)> {
    let n = kernel.rows();
    let sqrt_b: Vec<f64> = b_k.iter().map(|x| x.sqrt()).collect();
    let total = n as f64 * sqrt_b.iter().sum::<f64>();
    poisson_sparsify_with(
        n,
        kernel.cols(),
        |i, j| kernel.get(i, j),
        |_, _| 0.0, // IBP does not need per-entry costs
        |_, j| sqrt_b[j],
        total,
        s,
        1.0,
        rng,
    )
}

/// Run Spar-IBP (Algorithm 6): sparsify every kernel, then IBP.
///
/// `s` is the absolute expected sample budget per kernel (the paper
/// sweeps s ∈ {5,10,15,20}·s₀(n)).
pub fn spar_ibp(
    kernels: &[Mat],
    bs: &[Vec<f64>],
    weights: &[f64],
    s: f64,
    params: &SinkhornParams,
    rng: &mut Rng,
) -> Result<SparIbpSolution> {
    let mut sketches = Vec::with_capacity(kernels.len());
    let mut stats = Vec::with_capacity(kernels.len());
    for (k, kernel) in kernels.iter().enumerate() {
        let (sk, st) = sparsify_ibp_kernel(kernel, &bs[k], s, rng)?;
        sketches.push(sk);
        stats.push(st);
    }
    let solution = ibp_barycenter_with(&sketches, bs, weights, params)?;
    Ok(SparIbpSolution { solution, stats })
}

/// [`spar_ibp`] result routed through the backend switch: solution,
/// per-kernel stats, and the engine that actually ran.
#[derive(Clone, Debug)]
pub struct SparIbpBackendSolution {
    /// The barycenter histogram and IBP loop diagnostics.
    pub solution: BarycenterSolution,
    /// One sparsifier diagnostic per input kernel.
    pub stats: Vec<SparsifyStats>,
    /// Which scaling engine actually produced the solution.
    pub backend: BackendKind,
}

/// The [`SolverSpec`]-consuming adapter behind the `spar-ibp` registry
/// entry (the barycenter sibling of
/// [`spar_sink_solve`](super::spar_sink::spar_sink_solve)): resolves the
/// per-kernel budget `s = s_multiplier · s₀(n)`, sparsifies every input
/// kernel through the log-kernel Appendix A.2 sampler — identical RNG
/// stream and stored kernel values to the linear sampler at moderate ε,
/// but exact `ln K̃` per entry — and dispatches the IBP scaling stage
/// through [`ScalingBackend::sparse_ibp`], honoring the
/// [`SolverSpec::backend`] override and the shrinkage θ (condition (ii)
/// mixing, default 1 = pure importance sampling like the paper entry
/// points) end to end.
///
/// The A.2 probability `p ∝ √b_j` is purely marginal, so the
/// cost-dependent factor of a
/// [`CostSource::Shared`](crate::api::CostSource) problem is the
/// cached cost matrix itself: the per-kernel log-kernel oracle reads
/// `−C/ε` from the [`CostArtifacts`](crate::engine::CostArtifacts)
/// instead of re-deriving the ground cost per (kernel, entry) —
/// bitwise-identical sketches either way.
pub fn spar_ibp_solve(
    problem: &OtProblem,
    spec: &SolverSpec,
    rng: &mut Rng,
) -> Result<SparIbpBackendSolution> {
    let Formulation::Barycenter { marginals, weights } = &problem.formulation else {
        return Err(Error::InvalidParam(
            "spar-ibp solves barycenter problems; use spar-sink for OT/UOT".into(),
        ));
    };
    let eps = problem.eps;
    let n = problem.cost.rows();
    // Barycenter supports are square, so the crate-wide budget
    // convention collapses to the paper's s₀(n).
    let s = super::sketch_budget(spec.s_multiplier, n, n);
    let backend = spec.backend.unwrap_or_default();
    let mut sketches = Vec::with_capacity(marginals.len());
    let mut stats = Vec::with_capacity(marginals.len());
    for b_k in marginals {
        let (sk, st) = poisson_sparsify_ibp_logk(
            n,
            |i, j| problem.cost.log_kernel_at(i, j, eps),
            b_k,
            s,
            spec.shrinkage,
            rng,
        )?;
        sketches.push(sk);
        stats.push(st);
    }
    let (solution, kind) =
        backend.sparse_ibp(&sketches, marginals, weights, eps, &spec.sinkhorn_params())?;
    Ok(SparIbpBackendSolution { solution, stats, backend: kind })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::l1_distance;
    use crate::ot::barycenter::ibp_barycenter;
    use crate::ot::cost::{gibbs_kernel, sq_euclidean_cost};
    use crate::solvers::backend::ScalingBackend;

    fn setup(n: usize) -> (Vec<Mat>, Vec<Vec<f64>>, Vec<f64>) {
        let pts: Vec<Vec<f64>> = (0..n).map(|i| vec![i as f64 / (n - 1) as f64]).collect();
        let cost = sq_euclidean_cost(&pts, &pts);
        let kernel = gibbs_kernel(&cost, 0.01);
        let hist = |mu: f64, s2: f64| -> Vec<f64> {
            let w: Vec<f64> =
                pts.iter().map(|p| (-(p[0] - mu).powi(2) / (2.0 * s2)).exp() + 1e-4).collect();
            let s: f64 = w.iter().sum();
            w.iter().map(|x| x / s).collect()
        };
        let bs = vec![hist(0.2, 0.003), hist(0.5, 0.004), hist(0.8, 0.003)];
        let kernels = vec![kernel.clone(), kernel.clone(), kernel];
        (kernels, bs, vec![1.0 / 3.0; 3])
    }

    #[test]
    fn approximates_ibp_barycenter() {
        let n = 64;
        let (kernels, bs, w) = setup(n);
        let params = SinkhornParams { delta: 1e-8, max_iters: 2000, strict: false };
        let exact = ibp_barycenter(&kernels, &bs, &w, &params).unwrap();
        let mut rng = Rng::seed_from(77);
        let budget = 40.0 * crate::metrics::s0(n);
        let approx = spar_ibp(&kernels, &bs, &w, budget, &params, &mut rng).unwrap();
        // The sketched geometric-mean update does not renormalize, so
        // compare shapes after normalization (the fig11 harness reports
        // the same normalized L1 error).
        let mass: f64 = approx.solution.q.iter().sum();
        assert!(mass.is_finite() && mass > 0.0);
        let qn: Vec<f64> = approx.solution.q.iter().map(|x| x / mass).collect();
        let err = l1_distance(&qn, &exact.q);
        assert!(err < 0.5, "L1 error {err}");
    }

    #[test]
    fn error_decreases_with_budget() {
        let n = 64;
        let (kernels, bs, w) = setup(n);
        let params = SinkhornParams { delta: 1e-8, max_iters: 2000, strict: false };
        let exact = ibp_barycenter(&kernels, &bs, &w, &params).unwrap();
        let mut rng = Rng::seed_from(79);
        let mut mean_err = |mult: f64| -> f64 {
            let reps = 5;
            let mut acc = 0.0;
            for _ in 0..reps {
                let budget = mult * crate::metrics::s0(n);
                let approx = spar_ibp(&kernels, &bs, &w, budget, &params, &mut rng).unwrap();
                acc += l1_distance(&approx.solution.q, &exact.q);
            }
            acc / reps as f64
        };
        let small = mean_err(5.0);
        let large = mean_err(40.0);
        assert!(large < small, "err did not decrease: {small} -> {large}");
    }

    #[test]
    fn solve_adapter_matches_legacy_bitwise_at_moderate_eps() {
        // The adapter samples through the log-kernel sampler but must
        // reproduce the legacy linear pipeline bit for bit wherever the
        // kernel has not underflowed.
        use crate::api::Method;
        let n = 48;
        let (kernels, bs, w) = setup(n);
        let pts: Vec<Vec<f64>> = (0..n).map(|i| vec![i as f64 / (n - 1) as f64]).collect();
        let cost = sq_euclidean_cost(&pts, &pts);
        let eps = 0.01;
        let problem = OtProblem::barycenter(cost, bs.clone(), w.clone(), eps);
        let spec = SolverSpec::new(Method::SparIbp).with_budget(12.0).with_seed(55);
        let mut r_api = Rng::seed_from(55);
        let api = spar_ibp_solve(&problem, &spec, &mut r_api).unwrap();
        assert_eq!(api.backend, BackendKind::Multiplicative);
        let mut r_legacy = Rng::seed_from(55);
        let legacy = spar_ibp(
            &kernels,
            &bs,
            &w,
            12.0 * crate::metrics::s0(n),
            &SinkhornParams::default(),
            &mut r_legacy,
        )
        .unwrap();
        assert_eq!(api.stats.len(), legacy.stats.len());
        for (x, y) in api.solution.q.iter().zip(&legacy.solution.q) {
            assert_eq!(x.to_bits(), y.to_bits(), "{x} vs {y}");
        }
    }

    #[test]
    fn solve_adapter_honors_log_backend_override() {
        use crate::api::Method;
        let n = 48;
        let (_, bs, w) = setup(n);
        let pts: Vec<Vec<f64>> = (0..n).map(|i| vec![i as f64 / (n - 1) as f64]).collect();
        let cost = sq_euclidean_cost(&pts, &pts);
        let problem = OtProblem::barycenter(cost, bs, w, 0.01);
        let spec = SolverSpec::new(Method::SparIbp)
            .with_budget(12.0)
            .with_backend(ScalingBackend::LogDomain);
        let mut rng = Rng::seed_from(57);
        let sol = spar_ibp_solve(&problem, &spec, &mut rng).unwrap();
        assert_eq!(sol.backend, BackendKind::LogDomain);
        let mass: f64 = sol.solution.q.iter().sum();
        assert!((mass - 1.0).abs() < 1e-9, "mass {mass}");
    }

    #[test]
    fn stats_budget_respected() {
        let n = 48;
        let (kernels, bs, w) = setup(n);
        let mut rng = Rng::seed_from(83);
        let budget = 10.0 * crate::metrics::s0(n);
        let sol = spar_ibp(&kernels, &bs, &w, budget, &SinkhornParams::default(), &mut rng)
            .unwrap();
        assert_eq!(sol.stats.len(), 3);
        for st in &sol.stats {
            assert!((st.nnz as f64) <= budget * 1.25, "nnz {} vs {budget}", st.nnz);
        }
    }
}
