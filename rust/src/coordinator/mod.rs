//! The serving coordinator: a batched, sharded distance-computation
//! service.
//!
//! The paper's echocardiogram pipeline (Section 6) reduces to computing
//! many pairwise WFR distances between video frames. This module turns
//! that into a production-shaped service:
//!
//! ```text
//!   clients ── submit(job) ──▶ bounded queue (backpressure)
//!                                  │
//!                       batcher thread (scheduler)
//!               groups jobs by (method, size bucket), then
//!           routes each batch by its cost FINGERPRINT: one
//!          fingerprint → one shard (round-robin otherwise)
//!                                  │
//!              ┌───────────┬───────┴───────┬───────────┐
//!           shard 0     shard 1         shard …     shard S-1
//!        (bounded queue: FIFO submit, LIFO pop by own workers,
//!           FIFO pop by stealers — oldest batch steals first)
//!              │           │               │           │
//!           worker(s) per shard; an idle worker STEALS the
//!           oldest batch from the deepest other shard, then
//!            solves each job through `api::solve` (one
//!           dispatch surface for every registered method)
//!                                  │
//!                 per-job response channels + metrics
//!                 (global + per-shard [`ShardStats`])
//! ```
//!
//! Distance (pairwise WFR) and fixed-support barycenter jobs share the
//! same queue, batcher and worker pool — a [`BarycenterJob`] rides the
//! identical path via [`DistanceService::submit_barycenter`], honoring
//! per-job backend overrides and feeding the same per-method
//! log-escalation counters.
//!
//! * The submission queue is bounded: `submit` blocks once `queue_cap`
//!   jobs are in flight, while the non-blocking
//!   [`DistanceService::try_submit`] refuses with
//!   [`SubmitRejection::Busy`] instead — the admission-control path
//!   the HTTP gateway in [`crate::net`] surfaces as
//!   `429 Too Many Requests`. The per-shard queues are bounded too, so
//!   backpressure propagates shard → scheduler → `submit` instead of
//!   growing memory.
//! * The batcher flushes a batch when it reaches `max_batch` jobs or
//!   `batch_window` elapses, whichever comes first — the same policy as
//!   continuous-batching LLM servers, adapted to solver jobs.
//! * Fingerprint-affine routing keeps every artifact-cache hit on one
//!   shard's workers (cache-warm LIFO pop); work stealing bounds tail
//!   latency when the fingerprint distribution is skewed. Neither
//!   changes results: solutions are bitwise identical at any
//!   `shards`/`steal` setting (pinned by the `cache_parity` and
//!   `thread_determinism` suites).
//! * Latency/throughput metrics are recorded per job and exposed as a
//!   histogram snapshot ([`metrics::MetricsSnapshot`]) with per-shard
//!   depth/busy/stolen gauges.

mod jobs;
mod metrics;
mod scheduler;
mod service;
mod shard;
mod steal;

pub use jobs::{
    BarycenterJob, BarycenterResult, DistanceJob, DistanceResult, Measure, Method, ProblemSpec,
};
pub use metrics::{
    render_balancer_prometheus, BalancerBackendStats, LatencyHistogram, MetricsSnapshot,
    ShardStats,
};
pub use service::{CoordinatorConfig, DistanceService, SubmitRejection};
