//! # Spar-Sink — importance sparsification for the Sinkhorn algorithm
//!
//! Production-quality reproduction of *“Importance Sparsification for
//! Sinkhorn Algorithm”* (Li, Yu, Li & Meng, JMLR 2023) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the full solver library: exact entropic OT/UOT
//!   Sinkhorn and IBP barycenter solvers, the paper's Spar-Sink /
//!   Spar-IBP importance-sparsified solvers, every evaluated baseline
//!   (Greenkhorn, Screenkhorn, Nys-Sink, Robust-Nys-Sink, Rand-Sink),
//!   workload generators, a batched distance-matrix coordinator, the
//!   experiment harness regenerating every figure/table, and the PJRT
//!   runtime that executes the AOT-compiled L2/L1 artifacts.
//! * **L2 (python/compile/model.py)** — JAX definition of the fused
//!   Sinkhorn scaling blocks and objectives, lowered once to HLO text.
//! * **L1 (python/compile/kernels/)** — Pallas tile kernels for the
//!   matvec+scale hot-spot.
//!
//! Python never runs on the request path: `make artifacts` is build-time
//! only and the `repro` binary is self-contained afterwards.
//!
//! ## Quick start
//!
//! ```no_run
//! use spar_sink::ot::cost::sq_euclidean_cost;
//! use spar_sink::ot::sinkhorn::{sinkhorn_ot, SinkhornParams};
//! use spar_sink::solvers::spar_sink::{spar_sink_ot, SparSinkParams};
//! use spar_sink::rng::Rng;
//!
//! let n = 256;
//! let mut rng = Rng::seed_from(7);
//! let pts: Vec<Vec<f64>> = (0..n).map(|_| vec![rng.uniform(), rng.uniform()]).collect();
//! let cost = sq_euclidean_cost(&pts, &pts);
//! let a = vec![1.0 / n as f64; n];
//! let b = vec![1.0 / n as f64; n];
//! let eps = 0.05;
//! let kernel = cost.map(|c| (-c / eps).exp());
//! let exact = sinkhorn_ot(&kernel, &cost, &a, &b, eps, &SinkhornParams::default()).unwrap();
//! let approx = spar_sink_ot(&cost, &a, &b, eps, 8.0, &SparSinkParams::default(), &mut rng).unwrap();
//! println!("exact {:.6} sparse {:.6}", exact.objective, approx.solution.objective);
//! ```

pub mod bench;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod experiments;
pub mod linalg;
pub mod metrics;
pub mod ot;
pub mod pool;
pub mod rng;
pub mod runtime;
pub mod solvers;
pub mod sparse;
pub mod util;

pub use error::{Error, Result};
