"""Pure-jnp correctness oracles for the L1 Pallas kernels and L2 blocks.

Every Pallas kernel and every lowered block in ``model.py`` has a reference
implementation here written with plain ``jnp`` ops; pytest asserts
``allclose`` between the two across shape/dtype sweeps (hypothesis).
"""

from __future__ import annotations

import jax.numpy as jnp


def kv_scale_ref(kmat, v, a):
    """``u = a / (K @ v)`` — oracle for ``sinkhorn_pallas.kv_scale``."""
    return a / (kmat @ v)


def ktu_scale_ref(kmat, u, b):
    """``v = b / (K.T @ u)`` — oracle for ``sinkhorn_pallas.ktu_scale``."""
    return b / (kmat.T @ u)


def sinkhorn_block_ref(kmat, a, b, u, v, rho, n_iters):
    """Reference for ``model.sinkhorn_block``: ``n_iters`` scaling steps.

    ``rho = 1`` reproduces Algorithm 1 (balanced OT); ``rho = lam/(lam+eps)``
    reproduces Algorithm 2 (unbalanced OT).  Returns the updated scalings and
    the L1 displacement of the final step (the paper's stopping statistic).
    """
    err = jnp.zeros((), kmat.dtype)
    for _ in range(n_iters):
        u_prev, v_prev = u, v
        u = (a / (kmat @ v)) ** rho
        v = (b / (kmat.T @ u)) ** rho
        err = jnp.sum(jnp.abs(u - u_prev)) + jnp.sum(jnp.abs(v - v_prev))
    return u, v, err


def plan_ref(kmat, u, v):
    """Transport plan ``T = diag(u) K diag(v)`` for column scalings."""
    return u.reshape(-1, 1) * kmat * v.reshape(1, -1)


def ot_objective_ref(kmat, cost, u, v, eps):
    """Entropic OT objective <T, C> - eps * H(T) for T = diag(u) K diag(v)."""
    t = plan_ref(kmat, u, v)
    entropy = -jnp.sum(t * (jnp.log(jnp.where(t > 0, t, 1.0)) - 1.0))
    return jnp.sum(t * cost) - eps * entropy


def kl_ref(x, y):
    """Generalized KL(x || y) = sum x log(x/y) - x + y (0 log 0 = 0)."""
    ratio = jnp.where(x > 0, x / y, 1.0)
    return jnp.sum(jnp.where(x > 0, x * jnp.log(ratio), 0.0) - x + y)


def uot_objective_ref(kmat, cost, a, b, u, v, lam, eps):
    """Entropic UOT objective (Eq. 10 of the paper)."""
    t = plan_ref(kmat, u, v)
    entropy = -jnp.sum(t * (jnp.log(jnp.where(t > 0, t, 1.0)) - 1.0))
    row = jnp.sum(t, axis=1)
    col = jnp.sum(t, axis=0)
    return (
        jnp.sum(t * cost)
        + lam * kl_ref(row, a)
        + lam * kl_ref(col, b)
        - eps * entropy
    )


def sqeuclid_cost_ref(x, y):
    """Pairwise squared-Euclidean cost C_ij = ||x_i - y_j||^2."""
    xx = jnp.sum(x * x, axis=1, keepdims=True)
    yy = jnp.sum(y * y, axis=1, keepdims=True)
    return jnp.maximum(xx + yy.T - 2.0 * (x @ y.T), 0.0)
