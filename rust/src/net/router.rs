//! Request routing: one parsed [`Request`] in, one [`Response`] out.
//!
//! The route table is the gateway's contract surface:
//!
//! | method | path          | behavior                                        |
//! |--------|---------------|-------------------------------------------------|
//! | POST   | `/solve`      | decode a distance job, `try_submit`, wait, JSON |
//! | POST   | `/barycenter` | same for fixed-support barycenters              |
//! | GET    | `/metrics`    | Prometheus text exposition of the snapshot      |
//! | GET    | `/healthz`    | `200 ok` serving / `503 draining`               |
//!
//! Admission control is the load-bearing part: submissions go through
//! [`DistanceService::try_submit`], so a full coordinator queue answers
//! `429 Too Many Requests` (with `retry-after`) instead of parking the
//! connection thread — the accept loop never stalls behind a saturated
//! solver (pinned by `tests/gateway_integration.rs`).

use crate::coordinator::{DistanceService, SubmitRejection};
use crate::net::codec;
use crate::net::http::Request;
use crate::net::response::Response;
use crate::util::json::Json;

/// Dispatch one request against the service. `draining` is the
/// gateway's lifecycle flag: while set, probes answer `503` and no new
/// jobs are admitted (in-flight jobs still complete).
pub fn handle(service: &DistanceService, req: &Request, draining: bool) -> Response {
    let path = req.path.split('?').next().unwrap_or("");
    match (req.method.as_str(), path) {
        ("GET", "/healthz") => healthz(draining),
        ("GET", "/metrics") => {
            Response::text(200, "text/plain; version=0.0.4", service.metrics().render_prometheus())
        }
        ("POST", "/solve") => submit_distance(service, req, draining),
        ("POST", "/barycenter") => submit_barycenter(service, req, draining),
        (_, "/healthz" | "/metrics") => method_not_allowed("GET"),
        (_, "/solve" | "/barycenter") => method_not_allowed("POST"),
        _ => Response::error(404, &format!("no such endpoint '{path}'")),
    }
}

fn healthz(draining: bool) -> Response {
    if draining {
        Response::json(503, &Json::obj(vec![("status", Json::str("draining"))]))
    } else {
        Response::json(200, &Json::obj(vec![("status", Json::str("ok"))]))
    }
}

fn method_not_allowed(allow: &'static str) -> Response {
    Response::error(405, &format!("method not allowed (use {allow})"))
        .with_header("allow", allow.to_string())
}

/// Parse the request body as a JSON document (strict UTF-8, non-empty).
fn parse_body(req: &Request) -> Result<Json, Response> {
    if req.body.is_empty() {
        return Err(Response::error(400, "missing JSON body"));
    }
    let text = std::str::from_utf8(&req.body)
        .map_err(|_| Response::error(400, "body is not valid UTF-8"))?;
    Json::parse(text).map_err(|e| Response::error(400, &format!("bad JSON payload: {e}")))
}

/// Map a refused submission to its wire status: `Busy` is the
/// transient 429 (retry after backing off), `Stopped` the terminal 503.
fn rejected(rejection: SubmitRejection) -> Response {
    match rejection {
        SubmitRejection::Busy => {
            Response::error(429, &rejection.to_string()).with_header("retry-after", "1".to_string())
        }
        SubmitRejection::Stopped => Response::error(503, &rejection.to_string()),
    }
}

fn submit_distance(service: &DistanceService, req: &Request, draining: bool) -> Response {
    if draining {
        return rejected(SubmitRejection::Stopped);
    }
    let payload = match parse_body(req) {
        Ok(v) => v,
        Err(resp) => return resp,
    };
    let job = match codec::decode_distance_job(&payload) {
        Ok(job) => job,
        Err(e) => return Response::error(400, &e),
    };
    match service.try_submit(job) {
        Ok(rx) => match rx.recv() {
            Ok(result) => Response::json(200, &codec::distance_result_json(&result)),
            Err(_) => Response::error(500, "worker dropped the response channel"),
        },
        Err(rejection) => rejected(rejection),
    }
}

fn submit_barycenter(service: &DistanceService, req: &Request, draining: bool) -> Response {
    if draining {
        return rejected(SubmitRejection::Stopped);
    }
    let payload = match parse_body(req) {
        Ok(v) => v,
        Err(resp) => return resp,
    };
    let job = match codec::decode_barycenter_job(&payload) {
        Ok(job) => job,
        Err(e) => return Response::error(400, &e),
    };
    match service.try_submit_barycenter(job) {
        Ok(rx) => match rx.recv() {
            Ok(result) => Response::json(200, &codec::barycenter_result_json(&result)),
            Err(_) => Response::error(500, "worker dropped the response channel"),
        },
        Err(rejection) => rejected(rejection),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::CoordinatorConfig;

    fn small_service() -> DistanceService {
        DistanceService::start(CoordinatorConfig {
            workers: 1,
            shards: 1,
            ..CoordinatorConfig::default()
        })
    }

    fn request(method: &str, path: &str, body: &[u8]) -> Request {
        Request {
            method: method.to_string(),
            path: path.to_string(),
            headers: Vec::new(),
            body: body.to_vec(),
        }
    }

    fn body_json(resp: &Response) -> Json {
        Json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap()
    }

    #[test]
    fn unknown_paths_and_wrong_methods_have_exact_statuses() {
        let service = small_service();
        let resp = handle(&service, &request("GET", "/nope", b""), false);
        assert_eq!(resp.status, 404);
        let resp = handle(&service, &request("DELETE", "/solve", b""), false);
        assert_eq!(resp.status, 405);
        assert_eq!(resp.extra, vec![("allow", "POST".to_string())]);
        let resp = handle(&service, &request("POST", "/metrics", b""), false);
        assert_eq!(resp.status, 405);
        assert_eq!(resp.extra, vec![("allow", "GET".to_string())]);
        service.shutdown();
    }

    #[test]
    fn bad_payloads_answer_400_with_a_json_error_body() {
        let service = small_service();
        for body in [&b""[..], b"not json", b"{\"source\": 1}"] {
            let resp = handle(&service, &request("POST", "/solve", body), false);
            assert_eq!(resp.status, 400, "{body:?}");
            let err = body_json(&resp);
            assert!(err.get("error").and_then(|e| e.as_str()).is_some(), "{body:?}");
        }
        service.shutdown();
    }

    #[test]
    fn healthz_reports_the_drain_state_and_draining_refuses_jobs() {
        let service = small_service();
        assert_eq!(handle(&service, &request("GET", "/healthz", b""), false).status, 200);
        let resp = handle(&service, &request("GET", "/healthz", b""), true);
        assert_eq!(resp.status, 503);
        assert_eq!(body_json(&resp).get("status").unwrap().as_str(), Some("draining"));
        let resp = handle(&service, &request("POST", "/solve", b"{}"), true);
        assert_eq!(resp.status, 503);
        service.shutdown();
    }

    #[test]
    fn solve_round_trips_through_the_codec() {
        let service = small_service();
        let payload = br#"{
            "id": 5,
            "source": {"points": [[0.0], [1.0]], "mass": [0.5, 0.5]},
            "target": {"points": [[0.25], [0.75]], "mass": [0.5, 0.5]},
            "method": "sinkhorn",
            "spec": {"eps": 0.1, "max_iters": 200}
        }"#;
        let resp = handle(&service, &request("POST", "/solve", payload), false);
        assert_eq!(resp.status, 200);
        let result = body_json(&resp);
        assert_eq!(result.get("id").unwrap().as_f64(), Some(5.0));
        assert!(result.get("error").is_none());
        let distance = result.get("distance").unwrap().as_f64().unwrap();
        assert!(distance.is_finite() && distance >= 0.0);
        // Query strings are stripped before matching.
        assert_eq!(handle(&service, &request("GET", "/healthz?verbose=1", b""), false).status, 200);
        service.shutdown();
    }
}
