//! Content-addressed artifact cache: [`Fingerprint`] →
//! [`CostArtifacts`] with a byte-budget LRU, per-fingerprint
//! single-flight builds, and hit/miss/eviction counters.
//!
//! Consumers call [`ArtifactCache::get_or_build`]: the first caller for
//! a fingerprint becomes its builder; everyone else either gets the
//! resident `Arc` immediately (a hit) or — while the build is in
//! flight — blocks on that fingerprint's slot and receives the built
//! artifacts when they publish (also a hit: the build ran exactly once).
//! Builds run OUTSIDE the map lock, so a long kernel build on one
//! fingerprint never stalls lookups or builds on other fingerprints —
//! the many-ε sweep shape (`fig11`, `smalleps`) where every ε is its own
//! fingerprint. Eviction keeps resident bytes at or below the budget at
//! all times: accounting happens at publish time, a building slot is
//! never evicted, and an artifact larger than the whole budget is handed
//! to its caller (and any waiters) but never retained. A build that
//! panics poisons nothing permanently — the slot is cleared, waiters
//! wake and retry, and the next caller builds afresh.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

use super::artifacts::{CostArtifacts, CostHandle, Fingerprint};
use crate::util::sync::{lock_unpoisoned, wait_unpoisoned};

/// Default byte budget for [`global_cache`] (overridable via the
/// `SPAR_SINK_CACHE_BYTES` env var): 512 MiB.
pub const DEFAULT_CACHE_BYTES: usize = 512 << 20;

/// Point-in-time cache counters/gauges, surfaced through
/// [`MetricsSnapshot`](crate::coordinator::MetricsSnapshot).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Lookups served from a resident artifact — including lookups that
    /// blocked on an in-flight build and received its published result.
    pub hits: u64,
    /// Lookups that had to build (exactly one per single-flight group).
    pub misses: u64,
    /// Artifacts dropped to respect the byte budget (including
    /// oversized artifacts never retained).
    pub evictions: u64,
    /// Resident artifact count (ready slots only).
    pub entries: usize,
    /// In-flight builds (building slots; they hold no resident bytes
    /// and are never evicted).
    pub building: usize,
    /// Resident bytes (always ≤ `byte_budget`).
    pub bytes: usize,
    /// Configured byte budget.
    pub byte_budget: usize,
}

impl CacheStats {
    /// One-line rendering for service metrics output.
    pub fn render(&self) -> String {
        format!(
            "{} hits / {} misses / {} evictions, {} entries + {} building ({} B / {} B budget)",
            self.hits,
            self.misses,
            self.evictions,
            self.entries,
            self.building,
            self.bytes,
            self.byte_budget
        )
    }
}

/// Shared state of one in-flight build. Waiters grab an `Arc` to it
/// under the map lock, then wait on `cond` (paired with the map mutex)
/// until `outcome` is set: `Some(artifacts)` = published (possibly
/// oversized, i.e. not resident), `None` = the build panicked and the
/// slot was cleared — wake up and retry from the top.
struct BuildState {
    cond: Condvar,
    outcome: OnceLock<Option<Arc<CostArtifacts>>>,
}

impl BuildState {
    fn new() -> Self {
        BuildState { cond: Condvar::new(), outcome: OnceLock::new() }
    }
}

/// A resident (published) artifact plus its LRU accounting.
struct ReadySlot {
    artifacts: Arc<CostArtifacts>,
    bytes: usize,
    last_used: u64,
}

/// One map slot: either an in-flight single-flight build or a resident
/// artifact.
enum Slot {
    Building(Arc<BuildState>),
    Ready(ReadySlot),
}

struct Inner {
    entries: HashMap<Fingerprint, Slot>,
    /// Resident bytes across `Ready` slots (building slots hold none).
    bytes: usize,
    tick: u64,
}

/// The content-addressed, byte-budgeted LRU artifact cache with
/// per-fingerprint single-flight builds.
pub struct ArtifactCache {
    byte_budget: usize,
    inner: Mutex<Inner>,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

/// Clears a building slot if its build unwinds, so a panicking build
/// never wedges later callers: the slot is removed, the outcome is
/// marked poisoned, and every waiter is woken to retry. Defused (via
/// `std::mem::forget`) on the successful publish path.
struct BuildGuard<'a> {
    cache: &'a ArtifactCache,
    fingerprint: Fingerprint,
    state: &'a Arc<BuildState>,
}

impl Drop for BuildGuard<'_> {
    fn drop(&mut self) {
        let mut inner = lock_unpoisoned(&self.cache.inner);
        if matches!(
            inner.entries.get(&self.fingerprint),
            Some(Slot::Building(s)) if Arc::ptr_eq(s, self.state)
        ) {
            inner.entries.remove(&self.fingerprint);
        }
        // Mark the outcome poisoned only AFTER the slot is out of the
        // map, and under the map lock: lookups check the outcome while
        // holding that lock, so none can ever observe a still-mapped
        // building slot with a poisoned outcome — which would send its
        // retry loop spinning without ever releasing the mutex.
        let _ = self.state.outcome.set(None);
        drop(inner);
        self.state.cond.notify_all();
    }
}

impl ArtifactCache {
    /// A cache retaining at most `byte_budget` bytes of artifacts.
    pub fn new(byte_budget: usize) -> Self {
        ArtifactCache {
            byte_budget,
            inner: Mutex::new(Inner { entries: HashMap::new(), bytes: 0, tick: 0 }),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    /// Budget from `SPAR_SINK_CACHE_BYTES`, else [`DEFAULT_CACHE_BYTES`].
    pub fn with_default_budget() -> Self {
        let budget = std::env::var("SPAR_SINK_CACHE_BYTES")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(DEFAULT_CACHE_BYTES);
        Self::new(budget)
    }

    /// Look up a resident artifact (refreshes its LRU position; counts
    /// as neither hit nor miss — use [`ArtifactCache::get_or_build`] on
    /// solve paths). Returns `None` for absent fingerprints AND for
    /// builds still in flight — `peek` never blocks.
    pub fn peek(&self, fingerprint: &Fingerprint) -> Option<CostHandle> {
        let mut inner = lock_unpoisoned(&self.inner);
        inner.tick += 1;
        let tick = inner.tick;
        match inner.entries.get_mut(fingerprint) {
            Some(Slot::Ready(slot)) => {
                slot.last_used = tick;
                Some(CostHandle::new(slot.artifacts.clone()))
            }
            _ => None,
        }
    }

    /// Return the resident artifact for `fingerprint`, building it via
    /// `build` on a miss.
    ///
    /// Single-flight, per fingerprint: the first caller inserts a
    /// building slot, releases the map lock, builds OUTSIDE it, and
    /// publishes; concurrent callers for the SAME fingerprint block on
    /// the slot and receive the published `Arc` (counted as hits — the
    /// build ran exactly once), while callers for OTHER fingerprints
    /// hit, miss, and build entirely unimpeded. LRU accounting and
    /// eviction happen at publish time; a building slot is never
    /// evicted. If `build` panics, the slot is cleared and waiters
    /// retry, so the next caller builds afresh instead of deadlocking
    /// on a poisoned slot.
    pub fn get_or_build(
        &self,
        fingerprint: Fingerprint,
        build: impl FnOnce() -> Arc<CostArtifacts>,
    ) -> CostHandle {
        let mut inner = lock_unpoisoned(&self.inner);
        loop {
            inner.tick += 1;
            let tick = inner.tick;
            match inner.entries.get_mut(&fingerprint) {
                Some(Slot::Ready(slot)) => {
                    slot.last_used = tick;
                    self.hits.fetch_add(1, Ordering::Relaxed);
                    return CostHandle::new(slot.artifacts.clone());
                }
                Some(Slot::Building(state)) => {
                    let state = Arc::clone(state);
                    loop {
                        if let Some(outcome) = state.outcome.get() {
                            match outcome {
                                Some(artifacts) => {
                                    // The in-flight build published
                                    // (resident or oversized): share it.
                                    self.hits.fetch_add(1, Ordering::Relaxed);
                                    return CostHandle::new(artifacts.clone());
                                }
                                // Poisoned build: the slot is gone;
                                // re-examine the map (someone else may
                                // already be rebuilding).
                                None => break,
                            }
                        }
                        inner = wait_unpoisoned(&state.cond, inner);
                    }
                }
                None => break,
            }
        }
        // This caller is the builder for `fingerprint`.
        self.misses.fetch_add(1, Ordering::Relaxed);
        let state = Arc::new(BuildState::new());
        inner.entries.insert(fingerprint, Slot::Building(Arc::clone(&state)));
        drop(inner);

        let artifacts = {
            // The guard stays armed through the assert: a mismatch panic
            // must clear the slot like any other failed build, not wedge
            // the fingerprint's waiters forever.
            let guard = BuildGuard { cache: self, fingerprint, state: &state };
            let artifacts = build();
            debug_assert_eq!(artifacts.fingerprint(), fingerprint, "artifact/fingerprint mismatch");
            std::mem::forget(guard);
            artifacts
        };
        let _ = state.outcome.set(Some(artifacts.clone()));
        let bytes = artifacts.bytes();
        let handle = CostHandle::new(artifacts.clone());

        let mut inner = lock_unpoisoned(&self.inner);
        inner.tick += 1;
        let tick = inner.tick;
        if bytes > self.byte_budget {
            // Oversized: the caller and any waiters still get it, but it
            // is never resident (the budget invariant holds at all
            // times) — remove the building slot so later lookups rebuild.
            self.evictions.fetch_add(1, Ordering::Relaxed);
            if matches!(
                inner.entries.get(&fingerprint),
                Some(Slot::Building(s)) if Arc::ptr_eq(s, &state)
            ) {
                inner.entries.remove(&fingerprint);
            }
            drop(inner);
            state.cond.notify_all();
            return handle;
        }
        inner.entries.insert(
            fingerprint,
            Slot::Ready(ReadySlot { artifacts, bytes, last_used: tick }),
        );
        inner.bytes += bytes;
        while inner.bytes > self.byte_budget {
            // Evict the strictly least-recently-used READY slot; the
            // just-published slot carries the newest tick, so it is
            // evicted last — and the loop terminates because its bytes
            // alone fit the budget. Building slots are never victims.
            let victim = inner
                .entries
                // lint: allow(unordered-iter, "min_by_key over unique LRU ticks: exactly one victim regardless of iteration order")
                .iter()
                .filter_map(|(fp, slot)| match slot {
                    Slot::Ready(ready) if *fp != fingerprint => Some((*fp, ready.last_used)),
                    _ => None,
                })
                .min_by_key(|&(_, last_used)| last_used)
                .map(|(fp, _)| fp);
            let Some(fp) = victim else { break };
            if let Some(Slot::Ready(slot)) = inner.entries.remove(&fp) {
                inner.bytes -= slot.bytes;
                self.evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
        drop(inner);
        state.cond.notify_all();
        handle
    }

    /// Current counters and gauges.
    pub fn stats(&self) -> CacheStats {
        let inner = lock_unpoisoned(&self.inner);
        let (mut entries, mut building) = (0, 0);
        // lint: allow(unordered-iter, "order-independent counting of slot kinds")
        for slot in inner.entries.values() {
            match slot {
                Slot::Ready(_) => entries += 1,
                Slot::Building(_) => building += 1,
            }
        }
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            entries,
            building,
            bytes: inner.bytes,
            byte_budget: self.byte_budget,
        }
    }

    /// Drop every resident artifact (counters are preserved; in-flight
    /// builds keep their slot and publish normally).
    pub fn clear(&self) {
        let mut inner = lock_unpoisoned(&self.inner);
        inner.entries.retain(|_, slot| matches!(slot, Slot::Building(_)));
        inner.bytes = 0;
    }
}

/// The process-wide cache behind [`crate::api::solve_batch`] and the
/// CLI. Services that need isolated counters (the coordinator, tests)
/// hold their own [`ArtifactCache`].
pub fn global_cache() -> &'static ArtifactCache {
    static GLOBAL: OnceLock<ArtifactCache> = OnceLock::new();
    GLOBAL.get_or_init(ArtifactCache::with_default_budget)
}

#[cfg(test)]
mod tests {
    use super::super::artifacts::FormulationKey;
    use super::*;

    fn pts(n: usize, seed: u64) -> Vec<Vec<f64>> {
        let mut rng = crate::rng::Rng::seed_from(seed);
        (0..n).map(|_| vec![rng.uniform(), rng.uniform()]).collect()
    }

    fn build_for(seed: u64, eps: f64) -> (Fingerprint, Arc<CostArtifacts>) {
        let p = pts(16, seed);
        let key = FormulationKey::Balanced;
        let arts = CostArtifacts::for_sq_euclidean_support(&p, eps, key);
        (arts.fingerprint(), arts)
    }

    #[test]
    fn hit_returns_the_same_artifacts() {
        let cache = ArtifactCache::new(64 << 20);
        let (fp, arts) = build_for(1, 0.1);
        let first = cache.get_or_build(fp, || arts.clone());
        let second = cache.get_or_build(fp, || panic!("must not rebuild on a hit"));
        assert!(Arc::ptr_eq(&first.share(), &second.share()));
        let stats = cache.stats();
        assert_eq!((stats.hits, stats.misses, stats.evictions), (1, 1, 0));
        assert_eq!(stats.entries, 1);
        assert_eq!(stats.building, 0);
        assert!(stats.bytes > 0 && stats.bytes <= stats.byte_budget);
    }

    #[test]
    fn lru_eviction_respects_byte_budget() {
        let (_, probe) = build_for(1, 0.1);
        let one = probe.bytes();
        // Room for two artifacts, not three.
        let cache = ArtifactCache::new(2 * one + one / 2);
        for seed in 1..=5u64 {
            let (fp, arts) = build_for(seed, 0.1);
            cache.get_or_build(fp, || arts);
            let stats = cache.stats();
            assert!(stats.bytes <= stats.byte_budget, "{stats:?}");
            assert!(stats.entries <= 2, "{stats:?}");
        }
        let stats = cache.stats();
        assert_eq!(stats.misses, 5);
        assert_eq!(stats.evictions, 3);
        // The most recent fingerprint must still be resident.
        let (fp5, _) = build_for(5, 0.1);
        assert!(cache.peek(&fp5).is_some());
        let (fp1, _) = build_for(1, 0.1);
        assert!(cache.peek(&fp1).is_none());
    }

    #[test]
    fn oversized_artifact_is_served_but_not_retained() {
        let (fp, arts) = build_for(7, 0.1);
        let cache = ArtifactCache::new(arts.bytes() - 1);
        let handle = cache.get_or_build(fp, || arts.clone());
        assert!(Arc::ptr_eq(&handle.share(), &arts));
        let stats = cache.stats();
        assert_eq!(stats.entries, 0);
        assert_eq!(stats.building, 0);
        assert_eq!(stats.bytes, 0);
        assert_eq!(stats.evictions, 1);
    }

    #[test]
    fn clear_preserves_counters() {
        let cache = ArtifactCache::new(64 << 20);
        let (fp, arts) = build_for(9, 0.1);
        cache.get_or_build(fp, || arts.clone());
        cache.clear();
        let stats = cache.stats();
        assert_eq!(stats.entries, 0);
        assert_eq!(stats.bytes, 0);
        assert_eq!(stats.misses, 1);
        // Next lookup rebuilds.
        cache.get_or_build(fp, || arts);
        assert_eq!(cache.stats().misses, 2);
    }

    #[test]
    fn panicking_build_clears_the_slot_for_retry() {
        let cache = Arc::new(ArtifactCache::new(64 << 20));
        let (fp, arts) = build_for(11, 0.1);
        let poisoned = {
            let cache = cache.clone();
            std::thread::spawn(move || {
                cache.get_or_build(fp, || panic!("simulated build failure"))
            })
            .join()
        };
        assert!(poisoned.is_err(), "the build panic must propagate to its caller");
        let stats = cache.stats();
        assert_eq!(stats.building, 0, "poisoned slot must be cleared: {stats:?}");
        // The next caller rebuilds and publishes normally.
        let handle = cache.get_or_build(fp, || arts.clone());
        assert!(Arc::ptr_eq(&handle.share(), &arts));
        let stats = cache.stats();
        assert_eq!(stats.misses, 2, "{stats:?}");
        assert_eq!(stats.entries, 1, "{stats:?}");
    }
}
