//! Figure 3 — RMAE(UOT/WFR) versus s over C1-C3 × R1-R3 (kernel
//! densities ~70/50/30%), masses 5 & 3, ε = λ = 0.1.

use super::common::{exact_uot, rmae_over_reps, row, run_method_uot, wfr_cost_at_density, Method};
use super::{ExperimentOutput, Profile};
use crate::data::synthetic::{instance, Scenario, SparsityRegime};
use crate::rng::Rng;
use crate::util::json::Json;
use crate::util::table::{f, Table};

/// Figure 3: RMAE(UOT/WFR) vs subsample size s across C1–C3 × R1–R3.
pub fn run(profile: Profile) -> ExperimentOutput {
    let n = profile.pick(300, 1000);
    let reps = profile.reps(5, 100);
    let d = 5;
    let (lambda, eps) = (0.1, 0.1);
    let s_mults = [2.0, 4.0, 8.0, 16.0];

    let mut table = Table::new(&[
        "scenario", "regime", "method", "s/s0", "rmae", "se", "fail",
    ]);
    let mut rows = Vec::new();
    let mut rng = Rng::seed_from(0xF163);
    for scenario in Scenario::all() {
        for regime in SparsityRegime::all() {
            let inst = instance(scenario, n, d, 5.0, 3.0, &mut rng);
            let cost = wfr_cost_at_density(&inst.points, regime.density());
            let Ok(truth) = exact_uot(&cost, &inst.a, &inst.b, lambda, eps) else {
                table.row(vec![
                    scenario.name().into(),
                    regime.name().into(),
                    "(exact failed)".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                ]);
                continue;
            };
            for method in Method::all() {
                for &s_mult in &s_mults {
                    let (rmae, se, failures) = rmae_over_reps(
                        reps,
                        truth,
                        |r| {
                            run_method_uot(
                                method, &cost, &inst.a, &inst.b, lambda, eps, s_mult, r,
                            )
                        },
                        &mut rng,
                    );
                    table.row(vec![
                        scenario.name().into(),
                        regime.name().into(),
                        method.name().into(),
                        f(s_mult, 0),
                        f(rmae, 4),
                        f(se, 4),
                        failures.to_string(),
                    ]);
                    rows.push(row(vec![
                        ("scenario", Json::str(scenario.name())),
                        ("regime", Json::str(regime.name())),
                        ("method", Json::str(method.name())),
                        ("s_mult", Json::num(s_mult)),
                        ("rmae", Json::num(rmae)),
                        ("se", Json::num(se)),
                    ]));
                }
            }
        }
    }
    let text = format!(
        "Figure 3 — RMAE(UOT/WFR) vs s  (n = {n}, d = {d}, eps = lambda = 0.1, masses 5 & 3, {reps} reps)\n{}",
        table.render()
    );
    ExperimentOutput { id: "fig3", text, rows: Json::arr(rows) }
}
