//! Continuous and discrete distributions on top of the xoshiro core.
//!
//! Everything the paper's workloads need: Gaussian histograms (C1/C2),
//! Student-t histograms (C3), gamma/chi-square (for t-variates), and
//! weighted discrete sampling (for the with-replacement sampling
//! ablation).

use super::Rng;

impl Rng {
    /// Standard normal via Box–Muller (caches the second variate).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.take_cached_normal() {
            return z;
        }
        // Avoid u1 == 0 (log of zero).
        let mut u1 = self.uniform();
        while u1 <= f64::MIN_POSITIVE {
            u1 = self.uniform();
        }
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        let z0 = r * theta.cos();
        let z1 = r * theta.sin();
        self.set_cached_normal(z1);
        z0
    }

    /// Normal with given mean and standard deviation.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.normal()
    }

    /// Gamma(shape k, scale 1) via Marsaglia–Tsang (with the k < 1 boost).
    pub fn gamma(&mut self, shape: f64) -> f64 {
        assert!(shape > 0.0, "gamma shape must be positive");
        if shape < 1.0 {
            // Boosting: X_k = X_{k+1} * U^{1/k}.
            let g = self.gamma(shape + 1.0);
            let u = self.uniform().max(f64::MIN_POSITIVE);
            return g * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.uniform();
            let x2 = x * x;
            if u < 1.0 - 0.0331 * x2 * x2 {
                return d * v;
            }
            if u.ln() < 0.5 * x2 + d * (1.0 - v + v.ln()) {
                return d * v;
            }
        }
    }

    /// Chi-square with `df` degrees of freedom (gamma(df/2, 2)).
    #[inline]
    pub fn chi_square(&mut self, df: f64) -> f64 {
        2.0 * self.gamma(df / 2.0)
    }

    /// Student-t with `df` degrees of freedom: N / sqrt(Chi2_df / df).
    pub fn student_t(&mut self, df: f64) -> f64 {
        let z = self.normal();
        let c = self.chi_square(df).max(f64::MIN_POSITIVE);
        z / (c / df).sqrt()
    }

    /// Location/scale Student-t (the paper's `t5(mu, sigma^2)` notation:
    /// `sigma2` is the squared scale).
    #[inline]
    pub fn student_t_ls(&mut self, df: f64, mu: f64, sigma2: f64) -> f64 {
        mu + sigma2.sqrt() * self.student_t(df)
    }

    /// Sample an index from unnormalized non-negative weights
    /// (linear scan inversion — O(n); used in with-replacement ablation
    /// and Greenkhorn tie-breaking tests).
    pub fn weighted_choice(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted_choice needs positive total weight");
        let mut target = self.uniform() * total;
        for (i, &w) in weights.iter().enumerate() {
            target -= w;
            if target <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

/// Precomputed alias table for O(1) weighted sampling (Walker/Vose).
///
/// Used by the sampling-with-replacement ablation where s draws from an
/// n²-sized distribution would make the O(n) linear scan the bottleneck.
#[derive(Clone, Debug)]
pub struct AliasTable {
    prob: Vec<f64>,
    alias: Vec<usize>,
}

impl AliasTable {
    /// Build from unnormalized non-negative weights.
    pub fn new(weights: &[f64]) -> Self {
        let n = weights.len();
        assert!(n > 0);
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "alias table needs positive total weight");
        let scale = n as f64 / total;
        let mut prob: Vec<f64> = weights.iter().map(|w| w * scale).collect();
        let mut alias = vec![0usize; n];
        let mut small: Vec<usize> = Vec::with_capacity(n);
        let mut large: Vec<usize> = Vec::with_capacity(n);
        for (i, &p) in prob.iter().enumerate() {
            if p < 1.0 {
                small.push(i);
            } else {
                large.push(i);
            }
        }
        while let (Some(&s), Some(&l)) = (small.last(), large.last()) {
            small.pop();
            alias[s] = l;
            prob[l] = (prob[l] + prob[s]) - 1.0;
            if prob[l] < 1.0 {
                large.pop();
                small.push(l);
            }
        }
        // Remaining entries are numerically 1.
        for &i in small.iter().chain(large.iter()) {
            prob[i] = 1.0;
        }
        AliasTable { prob, alias }
    }

    /// Draw one index.
    #[inline]
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let i = rng.gen_range(self.prob.len());
        if rng.uniform() < self.prob[i] {
            i
        } else {
            self.alias[i]
        }
    }

    /// Number of outcomes in the table.
    pub fn len(&self) -> usize {
        self.prob.len()
    }

    /// Whether the table has no outcomes.
    pub fn is_empty(&self) -> bool {
        self.prob.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn moments(xs: &[f64]) -> (f64, f64) {
        let n = xs.len() as f64;
        let mean = xs.iter().sum::<f64>() / n;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n;
        (mean, var)
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from(17);
        let xs: Vec<f64> = (0..200_000).map(|_| r.normal()).collect();
        let (m, v) = moments(&xs);
        assert!(m.abs() < 0.02, "mean {m}");
        assert!((v - 1.0).abs() < 0.03, "var {v}");
    }

    #[test]
    fn gamma_moments() {
        let mut r = Rng::seed_from(19);
        let shape = 3.5;
        let xs: Vec<f64> = (0..200_000).map(|_| r.gamma(shape)).collect();
        let (m, v) = moments(&xs);
        assert!((m - shape).abs() < 0.05, "mean {m}");
        assert!((v - shape).abs() < 0.15, "var {v}");
    }

    #[test]
    fn gamma_small_shape() {
        let mut r = Rng::seed_from(23);
        let shape = 0.4;
        let xs: Vec<f64> = (0..200_000).map(|_| r.gamma(shape)).collect();
        let (m, _) = moments(&xs);
        assert!((m - shape).abs() < 0.02, "mean {m}");
        assert!(xs.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn chi_square_mean_is_df() {
        let mut r = Rng::seed_from(29);
        let xs: Vec<f64> = (0..100_000).map(|_| r.chi_square(5.0)).collect();
        let (m, _) = moments(&xs);
        assert!((m - 5.0).abs() < 0.1, "mean {m}");
    }

    #[test]
    fn student_t_symmetric_heavy_tails() {
        let mut r = Rng::seed_from(31);
        let xs: Vec<f64> = (0..200_000).map(|_| r.student_t(5.0)).collect();
        let (m, v) = moments(&xs);
        assert!(m.abs() < 0.03, "mean {m}");
        // Var of t_5 = 5/3.
        assert!((v - 5.0 / 3.0).abs() < 0.2, "var {v}");
    }

    #[test]
    fn weighted_choice_frequencies() {
        let mut r = Rng::seed_from(37);
        let w = [1.0, 2.0, 7.0];
        let mut counts = [0usize; 3];
        let n = 100_000;
        for _ in 0..n {
            counts[r.weighted_choice(&w)] += 1;
        }
        let f2 = counts[2] as f64 / n as f64;
        assert!((f2 - 0.7).abs() < 0.01, "freq {f2}");
    }

    #[test]
    fn alias_table_matches_weights() {
        let mut r = Rng::seed_from(41);
        let w = [0.5, 0.0, 3.0, 1.5];
        let table = AliasTable::new(&w);
        let mut counts = [0usize; 4];
        let n = 200_000;
        for _ in 0..n {
            counts[table.sample(&mut r)] += 1;
        }
        assert_eq!(counts[1], 0);
        let f2 = counts[2] as f64 / n as f64;
        assert!((f2 - 0.6).abs() < 0.01, "freq {f2}");
    }

    #[test]
    fn alias_table_single_element() {
        let mut r = Rng::seed_from(43);
        let table = AliasTable::new(&[2.0]);
        for _ in 0..10 {
            assert_eq!(table.sample(&mut r), 0);
        }
    }
}
